"""Shared benchmark utilities: timing, table formatting, result capture."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def _timeit(fn: Callable, args, kw, repeat: int):
    """([wall_seconds...], result-from-last-run)."""
    walls = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        walls.append(time.perf_counter() - t0)
    return walls, out


def timeit(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, best_seconds) — best-of-N wall time."""
    walls, out = _timeit(fn, args, kw, repeat)
    return out, min(walls)


def timeit_median(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, median_seconds) — median-of-N wall time.

    The gating statistic for perf assertions: robust to one slow outlier
    (CI noise) without rewarding a lucky fastest run the way best-of-N
    does.  ``result`` is from the last run."""
    walls, out = _timeit(fn, args, kw, repeat)
    return out, sorted(walls)[len(walls) // 2]


def table(headers: List[str], rows: List[List]) -> str:
    w = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
         for i, h in enumerate(headers)]
    out = ["  ".join(str(h).rjust(w[i]) for i, h in enumerate(headers))]
    out.append("  ".join("-" * w[i] for i in range(len(headers))))
    for r in rows:
        out.append("  ".join(str(c).rjust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)
