"""Roofline table (assignment §Roofline): reads the dry-run artifacts and
prints the three terms per (arch × shape × mesh), the dominant bottleneck,
the useful-flop ratio, and a one-line what-would-move-it note."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import save_result, table

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")

NOTES = {
    ("compute",): "more chips or lower-precision matmuls",
    ("memory",): "fuse/eliminate copies+transposes; seq-shard activations",
    ("collective",): "resharde params (EP/TP) to cut gathers; overlap",
}


def load(mesh: str = "single", tag: str = "") -> List[Dict]:
    suffix = f"__{tag}.json" if tag else ".json"
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}{suffix}"))):
        base = os.path.basename(path)
        if not tag and base.count("__") != 2:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def note_for(rec: Dict) -> str:
    dom = rec["roofline"]["dominant"]
    if dom == "collective":
        ag = rec["collectives"]["all-gather"]["bytes"]
        ar = rec["collectives"]["all-reduce"]["bytes"]
        if ag > ar:
            return "param all-gathers dominate: shard experts/params wider"
        return "grad all-reduce dominates: reduce-scatter + compress"
    if dom == "memory":
        if rec["op_census"]["transpose"] > 500:
            return "layout churn (transposes); pick matmul-friendly layouts"
        return "activation traffic; seq-shard / fuse elementwise"
    return "compute-bound: good — push batch or precision"


def run(mesh: str = "single", tag: str = ""):
    recs = load(mesh, tag)
    rows = []
    out = {}
    for r in recs:
        key = f"{r['arch']}×{r['shape']}"
        if r["status"] == "skip":
            rows.append([key, "skip", "-", "-", "-", "-", "-", "-"])
            continue
        if r["status"] == "fail":
            rows.append([key, "FAIL", "-", "-", "-", "-", "-", "-"])
            continue
        rl = r["roofline"]
        rows.append([
            key,
            f"{rl['t_compute']*1e3:.1f}",
            f"{rl['t_memory']*1e3:.1f}",
            f"{rl['t_collective']*1e3:.1f}",
            rl["dominant"],
            f"{rl['useful_flop_frac']*100:.0f}%",
            f"{rl['roofline_frac']*100:.2f}%",
            f"{r['memory']['peak_device_bytes']/2**30:.1f}",
        ])
        out[key] = dict(rl, peak_gib=r["memory"]["peak_device_bytes"] / 2**30,
                        note=note_for(r))
    print(f"Roofline — mesh={mesh}{' tag=' + tag if tag else ''} "
          f"(terms in ms/step/device; v5e: 197Tf bf16, 819GB/s HBM, "
          f"50GB/s ICI)")
    print(table(["arch×shape", "t_comp", "t_mem", "t_coll", "dominant",
                 "useful", "roofline", "GiB/dev"], rows))
    save_result(f"roofline_{mesh}{('_' + tag) if tag else ''}", out)
    return out


if __name__ == "__main__":
    import sys
    run(*(sys.argv[1:] or ["single"]))
