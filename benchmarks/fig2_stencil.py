"""Paper Fig 2: 2D stencil, 16 PEs, tiled init, ±40% synthetic noise, K=4.

Paper values: comm-based  max/avg 1.04, ext/int 0.06;
              coord-based max/avg 1.02, ext/int 0.072.

The paper's ext/int of ~0.06 at 16 PEs implies a large grid (surface/volume
→ 4/side per tile); we use 64×64 (256 objects/PE, tile side 16 ⇒ tiled
ext/int = 4·16/(2·16·16 - 4·16) ≈ 0.14 before noise... the paper's exact
grid size is unstated, so we report 32..96 and compare the *relations*:
both variants restore balance to ≤1.05 while keeping ext/int within ~20%
of the tiled optimum, coord slightly better balance / slightly worse
locality than comm (the paper's observation §V.A)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.core import api, metrics
from repro.sim import stencil, synthetic, viz


def run(grid: int = 64, pes: int = 16, k: int = 4, noise: float = 0.4,
        seed: int = 1):
    prob = stencil.stencil_2d(grid, grid, pes, mapping="tiled")
    prob = synthetic.random_pm(prob, noise, seed=seed)
    before = metrics.evaluate(prob)
    rows = [["initial", f"{before['max_avg_load']:.3f}",
             f"{before['ext_int_comm']:.3f}", "-", "-"]]
    out = dict(before=before)
    for variant in ("diff-comm", "diff-coord"):
        plan = api.run_strategy(variant, prob, k=k)
        out[variant] = plan.info
        rows.append([
            variant, f"{plan.info['max_avg_load']:.3f}",
            f"{plan.info['ext_int_comm']:.3f}",
            f"{plan.info['pct_migrations']*100:.1f}%",
            f"{plan.info['plan_seconds']:.2f}s",
        ])
        a = plan.assignment
        out[variant + "_locality"] = viz.locality_summary(a, grid, grid)
    print(f"Fig 2 — {grid}x{grid} stencil, {pes} PEs, ±{noise:.0%}, K={k}")
    print(table(["strategy", "max/avg", "ext/int", "%migr", "plan"], rows))
    print("paper: comm 1.04/.06, coord 1.02/.072 (relations: both balance; "
          "coord trades locality for roundness)")
    save_result("fig2_stencil", out)
    return out


if __name__ == "__main__":
    run()
