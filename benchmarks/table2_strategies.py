"""Paper Table II: 5 strategies × 3 synthetic 3D-stencil benchmarks
(8/32/128 PEs), mod-7 load injection.

Paper relations validated:
  * GreedyRefine: best max/avg (1.00), WORST ext/int, ~19% migrations;
  * METIS: best ext/int, ~87-99% migrations;
  * ParMETIS: middling balance, fewest migrations (hard-to-tune knob);
  * Diff-Comm/Diff-Coord: 1.02-1.14 max/avg, ext/int between GreedyRefine
    and METIS, 15-19% migrations — the middle ground the paper claims.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.runtime import cost as rt_cost
from repro.sim import scenarios, simulator, stencil, synthetic

BENCH = [(8, (8, 8, 8)), (32, (16, 16, 8)), (128, (32, 16, 16))]
STRATS = ["greedy-refine", "metis", "parmetis", "diff-comm", "diff-coord"]
# trigger-wrapped registry variants (runtime.triggers policies) — same
# planner, adaptive *when*; surfaced over a time-evolving replay where
# the wrapping matters (snapshot planning is identical to diff-comm)
TRIGGER_STRATS = ["diff-comm", "diff-comm+threshold", "diff-comm+predictive"]


def trigger_policy_section(steps: int = 200, lb_every: int = 10):
    """Replay the churn workload under each trigger-wrapped strategy
    (registry defaults — the registry is what is being surfaced here;
    the cost-coupled headline comparison lives in runtime_bench)."""
    from benchmarks.runtime_bench import MODEL as model

    prob, evolve = scenarios.get("bimodal-churn").instantiate()
    out = {}
    rows = []
    for strat in TRIGGER_STRATS:
        res = simulator.run_series(
            prob, evolve, steps=steps, lb_every=lb_every, strategy=strat,
            strategy_kwargs=dict(k=4), scan=True)
        total = float(rt_cost.series_modeled_seconds(res, model).sum())
        # honest per-policy migration cost: the executed exchange volume
        # priced by the same model (per-rebalance overhead charged only
        # at fired steps)
        per_step = np.asarray(
            model.migration_seconds(res.migrated_load.astype(np.float32)))
        migr_cost = float((per_step * res.lb_fired).sum())
        out[strat] = dict(
            rebalances=float(res.lb_fired.sum()),
            mean_max_avg=float(res.max_avg.mean()),
            migrated_load=float(res.migrated_load.sum()),
            migration_seconds=migr_cost,
            modeled_seconds=total,
        )
        rows.append([strat, int(res.lb_fired.sum()),
                     f"{res.max_avg.mean():.3f}",
                     f"{res.migrated_load.sum():.0f}",
                     f"{migr_cost:.0f}", f"{total:.0f}"])
    print(f"\nTrigger policies on bimodal-churn ({steps} steps)")
    print(table(["strategy", "rebalances", "mean max/avg",
                 "migrated load", "migr cost s", "modeled s"],
                rows))
    return out


def run(mapping: str = "striped"):
    from benchmarks.runtime_bench import MODEL as model

    out = {}
    for pes, dims in BENCH:
        prob = stencil.stencil_3d(*dims, pes, mapping=mapping)
        prob = synthetic.mod7(prob)
        rows = simulator.compare(
            prob, STRATS,
            strategy_kwargs={"diff-comm": dict(k=4), "diff-coord": dict(k=4)})
        print(f"\nBenchmark {pes} PEs ({dims[0]}x{dims[1]}x{dims[2]} "
              f"{mapping})")
        print(simulator.format_table(rows))
        out[pes] = {r.strategy: dict(r.after, **{
            k: v for k, v in r.info.items() if isinstance(v, (int, float))})
            for r in rows}
        out[f"{pes}_before"] = rows[0].before
        # honest migration-cost columns (§II metric 3): the load volume
        # each plan moves, priced by the runtime cost model
        mig_rows = []
        for r in rows:
            cost_s = float(model.migration_seconds(
                np.float32(r.info["migrated_load"])))
            out[pes][r.strategy]["migration_seconds"] = cost_s
            mig_rows.append([r.strategy,
                             f"{r.info['migrated_load']:.0f}",
                             f"{100 * r.after['pct_migrations']:.1f}%",
                             f"{cost_s:.0f}"])
        print(table(["strategy", "migrated load", "%objs", "migr cost s"],
                    mig_rows))

        by = out[pes]
        # paper's qualitative relations
        assert by["greedy-refine"]["max_avg_load"] <= 1.05
        assert by["metis"]["pct_migrations"] > 0.5, "METIS migrates heavily"
        assert (by["diff-comm"]["pct_migrations"]
                < by["metis"]["pct_migrations"] / 2), "diffusion migrates far less"
        # locality: diffusion never materially worse than GreedyRefine...
        assert (by["diff-comm"]["ext_int_comm"]
                < by["greedy-refine"]["ext_int_comm"] * 1.1), \
            "diffusion must not lose locality vs GreedyRefine"
        assert by["diff-comm"]["max_avg_load"] < 1.15
    # ...and strictly better where it matters (the largest benchmark —
    # the paper's gap also widens with scale, §VI.C)
    big = BENCH[-1][0]
    assert (out[big]["diff-comm"]["ext_int_comm"]
            < out[big]["greedy-refine"]["ext_int_comm"])
    out["trigger_policies"] = trigger_policy_section()
    save_result("table2_strategies", out)
    return out


if __name__ == "__main__":
    run()
