"""Sharded replay vs single-device replay: the whole step loop on a mesh.

Replays every registered scenario (sim/scenarios.py) twice — through the
single-device scanned path (``sim.simulator.run_series``) and through the
mesh-sharded replay runtime (``distributed.replay_shard`` — evolve,
trigger, sharded three-stage planning and the assignment update all
inside one ``shard_map``) — plus the PIC driver end-to-end (executed
particle exchange via the in-scan ``ppermute`` ring all-to-all,
``PICConfig(sharded_replay=True)``).  The headline gate is **parity, not
speed**: on an emulated CPU mesh the sharded wall time measures virtual-
device overhead, not distributed planning time (the same caveat
fig5_scaling documents), so wall numbers are reported honestly but not
asserted.  Every scenario must reproduce the single-device trajectory
**bit-for-bit** — per-step metrics, trigger fire steps, migration
counts/loads and final assignments (PIC: final particle order too).

Results are written twice: ``artifacts/bench/replay_shard_bench.json``
(legacy location) and the stable-schema ``BENCH_replay.json`` at the
repo root (schema ``replay-bench/v2``; keys are append-only — v2 adds
the ``manifest_method`` the PIC exchange resolved to (sort vs sort-free
counting scatter), keeping the perf trajectory attributable across
manifest-kernel changes; committed + CI-uploaded).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src:. python benchmarks/replay_shard_bench.py

(running the file directly forces the 8-virtual-device mesh itself when
XLA_FLAGS does not already pin a device count)
"""
from __future__ import annotations

import json
import os

SCHEMA = "replay-bench/v2"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_replay.json")

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")
PIC_FIELDS = ("max_avg", "ext_bytes", "int_bytes", "migrations",
              "migrated_bytes", "lb_steps", "final_x", "final_y")


def _parity(ref, got, fields):
    """Per-field bit-for-bit equality (wall-derived fields excluded —
    ``plan_seconds``/``step_seconds`` embed measured wall clock, which
    differs between *any* two runs, sharded or not)."""
    import numpy as np

    return {f: bool(np.array_equal(np.asarray(getattr(ref, f)),
                                   np.asarray(getattr(got, f))))
            for f in fields}


def _bench_scenarios(out, *, steps=200, lb_every=10, k=4):
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.distributed import replay_shard
    from repro.sim import scenarios, simulator

    out["scenarios"] = {}
    rows = []
    for name in scenarios.available():
        prob, evolve = scenarios.get(name).instantiate()
        kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
                  strategy_kwargs=dict(k=k))
        single, single_wall = timeit_median(
            lambda: simulator.run_series(prob, evolve, scan=True, **kw),
            repeat=REPEATS)
        mesh = replay_shard._resolve_mesh(None, None, (prob.num_nodes,))
        D = int(np.prod(mesh.devices.shape))
        sharded, sharded_wall = timeit_median(
            lambda: simulator.run_series_sharded(prob, evolve, **kw),
            repeat=REPEATS)
        par = _parity(single, sharded, SERIES_FIELDS)
        out["scenarios"][name] = dict(
            num_nodes=prob.num_nodes,
            num_shards=D,
            rebalances=float(single.lb_fired.sum()),
            migrated_load=float(single.migrated_load.sum()),
            single_wall_seconds=single_wall,
            sharded_wall_seconds=sharded_wall,
            parity=par,
            bit_for_bit=all(par.values()),
        )
        rows.append([name, prob.num_nodes, D, int(single.lb_fired.sum()),
                     f"{single_wall:.3f}", f"{sharded_wall:.3f}",
                     all(par.values())])
        assert all(par.values()), \
            f"sharded replay diverged from single-device on {name}: " \
            f"{ {f: v for f, v in par.items() if not v} }"
    print(f"\nscenario registry replay (diff-comm k={k}, {steps} steps, "
          f"median of {REPEATS})")
    print(table(["scenario", "P", "shards", "rebalances", "single s",
                 "sharded s", "bit-for-bit"], rows))


def _bench_pic(out, *, steps=60, lb_every=10):
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.distributed import replay_shard
    from repro.pic import driver

    base = dict(L=200, n_particles=20_000, steps=steps, k=2, rho=0.9,
                cx=10, cy=10, num_pes=8, mapping="striped",
                lb_every=lb_every, strategy="diff-comm",
                strategy_kwargs=dict(k=4))
    single_cfg = driver.PICConfig(scan=True, **base)
    sharded_cfg = driver.PICConfig(sharded_replay=True, **base)
    single, single_wall = timeit_median(
        lambda: driver.run(single_cfg), repeat=REPEATS)
    mesh = replay_shard._resolve_mesh(
        None, None, (base["n_particles"], base["num_pes"]))
    D = int(np.prod(mesh.devices.shape))
    sharded, sharded_wall = timeit_median(
        lambda: driver.run(sharded_cfg), repeat=REPEATS)
    from repro.runtime import migrate as rt_migrate

    par = _parity(single, sharded, PIC_FIELDS)
    conserved = bool(sharded.final_x.shape[0] == base["n_particles"]
                     and np.isfinite(sharded.final_x).all())
    out["pic"] = dict(
        n_particles=base["n_particles"],
        num_pes=base["num_pes"],
        # v2: which manifest build the executed exchange resolved to
        manifest_method=rt_migrate.resolve_method(
            "auto", n=base["n_particles"], num_nodes=base["num_pes"]),
        num_shards=D,
        rebalances=float(single.lb_steps.sum()),
        migrated_bytes=float(single.migrated_bytes.sum()),
        particles_conserved=conserved,
        single_wall_seconds=single_wall,
        sharded_wall_seconds=sharded_wall,
        parity=par,
        bit_for_bit=all(par.values()),
    )
    print(f"\nPIC driver 20k particles, {steps} steps, {D}-shard mesh, "
          f"executed in-scan exchange")
    print(table(
        ["path", "rebalances", "migrated bytes", "wall s", "bit-for-bit"],
        [["single", int(single.lb_steps.sum()),
          f"{single.migrated_bytes.sum():.0f}", f"{single_wall:.3f}", "-"],
         ["sharded", int(sharded.lb_steps.sum()),
          f"{sharded.migrated_bytes.sum():.0f}", f"{sharded_wall:.3f}",
          all(par.values())]]))
    assert conserved, "sharded exchange must conserve particles"
    assert all(par.values()), \
        f"sharded PIC replay diverged: " \
        f"{ {f: v for f, v in par.items() if not v} }"


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    payload = dict(
        schema=SCHEMA,
        generated_by="benchmarks/replay_shard_bench.py",
        repeats=REPEATS,
        **out,
    )
    path = os.path.abspath(BENCH_PATH)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
        f.write("\n")
    return path


def run():
    import jax

    from benchmarks.common import save_result

    out = {"devices": len(jax.devices()),
           "backend": jax.default_backend(),
           # wall numbers on a forced CPU mesh measure virtual-device
           # overhead, not distributed planning time — flagged so the
           # perf trajectory never reads them as a regression
           "emulated_mesh": "xla_force_host_platform_device_count"
                            in os.environ.get("XLA_FLAGS", "")}
    _bench_scenarios(out)
    _bench_pic(out)

    path = save_result("replay_shard_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    return out


if __name__ == "__main__":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()
    run()
