"""Sharded replay vs single-device replay: the whole step loop on a mesh.

Replays every registered scenario (sim/scenarios.py) twice — through the
single-device scanned path (``sim.simulator.run_series``) and through the
mesh-sharded replay runtime (``distributed.replay_shard`` — evolve,
trigger, sharded three-stage planning and the assignment update all
inside one ``shard_map``) — plus the PIC driver end-to-end (executed
particle exchange via the in-scan ``ppermute`` ring all-to-all,
``PICConfig(sharded_replay=True)``).  The headline gate is **parity, not
speed**: on an emulated CPU mesh the sharded wall time measures virtual-
device overhead, not distributed planning time (the same caveat
fig5_scaling documents), so wall numbers are reported honestly but not
asserted.  Every scenario must reproduce the single-device trajectory
**bit-for-bit** — per-step metrics, trigger fire steps, migration
counts/loads and final assignments (PIC: final particle order too).

Results are written twice: ``artifacts/bench/replay_shard_bench.json``
(legacy location) and the stable-schema ``BENCH_replay.json`` at the
repo root (schema ``replay-bench/v3``; keys are append-only — v2 added
the ``manifest_method`` the PIC exchange resolved to (sort vs sort-free
counting scatter), v3 adds the ``resilience`` section: a fault-injected
replay (one shard of the mesh dead mid-run) gated on completion,
finiteness, full evacuation and zero particle loss, with the degraded
post-fault peak load reported relative to the healthy run; committed +
CI-uploaded).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src:. python benchmarks/replay_shard_bench.py

(running the file directly forces the 8-virtual-device mesh itself when
XLA_FLAGS does not already pin a device count)
"""
from __future__ import annotations

import json
import os

SCHEMA = "replay-bench/v4"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_replay.json")

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")
PIC_FIELDS = ("max_avg", "ext_bytes", "int_bytes", "migrations",
              "migrated_bytes", "lb_steps", "final_x", "final_y")


def _parity(ref, got, fields):
    """Per-field bit-for-bit equality (wall-derived fields excluded —
    ``plan_seconds``/``step_seconds`` embed measured wall clock, which
    differs between *any* two runs, sharded or not)."""
    import numpy as np

    return {f: bool(np.array_equal(np.asarray(getattr(ref, f)),
                                   np.asarray(getattr(got, f))))
            for f in fields}


def _bench_scenarios(out, *, steps=200, lb_every=10, k=4):
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.distributed import replay_shard
    from repro.sim import scenarios, simulator

    out["scenarios"] = {}
    rows = []
    for name in scenarios.available():
        prob, evolve = scenarios.get(name).instantiate()
        kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
                  strategy_kwargs=dict(k=k))
        single, single_wall = timeit_median(
            lambda: simulator.run_series(prob, evolve, scan=True, **kw),
            repeat=REPEATS)
        mesh = replay_shard._resolve_mesh(None, None, (prob.num_nodes,))
        D = int(np.prod(mesh.devices.shape))
        sharded, sharded_wall = timeit_median(
            lambda: simulator.run_series_sharded(prob, evolve, **kw),
            repeat=REPEATS)
        par = _parity(single, sharded, SERIES_FIELDS)
        out["scenarios"][name] = dict(
            num_nodes=prob.num_nodes,
            num_shards=D,
            rebalances=float(single.lb_fired.sum()),
            migrated_load=float(single.migrated_load.sum()),
            single_wall_seconds=single_wall,
            sharded_wall_seconds=sharded_wall,
            parity=par,
            bit_for_bit=all(par.values()),
        )
        rows.append([name, prob.num_nodes, D, int(single.lb_fired.sum()),
                     f"{single_wall:.3f}", f"{sharded_wall:.3f}",
                     all(par.values())])
        assert all(par.values()), \
            f"sharded replay diverged from single-device on {name}: " \
            f"{ {f: v for f, v in par.items() if not v} }"
    print(f"\nscenario registry replay (diff-comm k={k}, {steps} steps, "
          f"median of {REPEATS})")
    print(table(["scenario", "P", "shards", "rebalances", "single s",
                 "sharded s", "bit-for-bit"], rows))


def _bench_pic(out, *, steps=60, lb_every=10):
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.distributed import replay_shard
    from repro.pic import driver

    base = dict(L=200, n_particles=20_000, steps=steps, k=2, rho=0.9,
                cx=10, cy=10, num_pes=8, mapping="striped",
                lb_every=lb_every, strategy="diff-comm",
                strategy_kwargs=dict(k=4))
    single_cfg = driver.PICConfig(scan=True, **base)
    sharded_cfg = driver.PICConfig(sharded_replay=True, **base)
    single, single_wall = timeit_median(
        lambda: driver.run(single_cfg), repeat=REPEATS)
    mesh = replay_shard._resolve_mesh(
        None, None, (base["n_particles"], base["num_pes"]))
    D = int(np.prod(mesh.devices.shape))
    sharded, sharded_wall = timeit_median(
        lambda: driver.run(sharded_cfg), repeat=REPEATS)
    from repro.runtime import migrate as rt_migrate

    par = _parity(single, sharded, PIC_FIELDS)
    conserved = bool(sharded.final_x.shape[0] == base["n_particles"]
                     and np.isfinite(sharded.final_x).all())
    out["pic"] = dict(
        n_particles=base["n_particles"],
        num_pes=base["num_pes"],
        # v2: which manifest build the executed exchange resolved to
        manifest_method=rt_migrate.resolve_method(
            "auto", n=base["n_particles"], num_nodes=base["num_pes"]),
        num_shards=D,
        rebalances=float(single.lb_steps.sum()),
        migrated_bytes=float(single.migrated_bytes.sum()),
        particles_conserved=conserved,
        single_wall_seconds=single_wall,
        sharded_wall_seconds=sharded_wall,
        parity=par,
        bit_for_bit=all(par.values()),
    )
    print(f"\nPIC driver 20k particles, {steps} steps, {D}-shard mesh, "
          f"executed in-scan exchange")
    print(table(
        ["path", "rebalances", "migrated bytes", "wall s", "bit-for-bit"],
        [["single", int(single.lb_steps.sum()),
          f"{single.migrated_bytes.sum():.0f}", f"{single_wall:.3f}", "-"],
         ["sharded", int(sharded.lb_steps.sum()),
          f"{sharded.migrated_bytes.sum():.0f}", f"{sharded_wall:.3f}",
          all(par.values())]]))
    assert conserved, "sharded exchange must conserve particles"
    assert all(par.values()), \
        f"sharded PIC replay diverged: " \
        f"{ {f: v for f, v in par.items() if not v} }"


def _bench_resilience(out, *, steps=120, lb_every=10, k=4):
    """Fault-injected replay: kill one shard mid-run, gate the recovery.

    The healthy and degraded runs share scenario, cadence and strategy;
    the only delta is a ``FaultSchedule`` with one ``die`` event at
    ``steps // 3``.  Gates (asserted, not just reported):

      * the degraded run completes with finite metrics end to end;
      * the final assignment has **zero** objects on the dead shard's
        nodes (full evacuation), and no plan was rejected;
      * the PIC fault run conserves every particle — its final
        particle-id-order positions equal the LB-free reference run
        exactly (the push physics never depended on the assignment);
      * the post-fault peak load stays bounded: with 1 of D shards dead
        the load-per-alive-node floor rises by D/(D-1), so the degraded
        steady-state peak must stay within ``DEGRADE_BOUND`` of the
        healthy post-fault mean peak (measured ~1.3–1.6x on the 8-shard
        CPU mesh; 3.0 leaves headroom without masking an evacuation
        that dumps everything on one node, which measures ~8x).

    Skipped (reported, not failed) on a 1-device mesh — killing the only
    shard has no correct answer."""
    import numpy as np

    from benchmarks.common import table
    from repro.distributed import replay_shard
    from repro.pic import driver
    from repro.runtime import resilience as rz
    from repro.sim import scenarios, simulator

    DEGRADE_BOUND = 3.0

    prob, evolve = scenarios.get("stencil-wave").instantiate()
    mesh = replay_shard._resolve_mesh(None, None, (prob.num_nodes,))
    D = int(np.prod(mesh.devices.shape))
    if D < 2:
        out["resilience"] = dict(skipped=True, num_shards=D,
                                 reason="needs >= 2 shards to kill one")
        print("\nresilience: skipped (1-shard mesh)")
        return

    fault_step = steps // 3
    dead_shard = D // 2
    kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
              strategy_kwargs=dict(k=k))
    healthy = simulator.run_series_sharded(prob, evolve, **kw)
    fs = rz.FaultSchedule(events=((fault_step, dead_shard, "die"),))
    degraded = simulator.run_series_sharded(prob, evolve, faults=fs, **kw)

    rpd = prob.num_nodes // D
    dead_nodes = np.arange(dead_shard * rpd, (dead_shard + 1) * rpd)
    evacuated = not np.isin(degraded.final_assignment, dead_nodes).any()
    finite = bool(np.isfinite(degraded.max_avg).all()
                  and np.isfinite(degraded.max_load).all())
    rejected = float(degraded.plan_rejected.sum())
    post = slice(fault_step + lb_every, None)  # past the evacuation spike
    healthy_peak = float(np.mean(healthy.max_load[post]))
    degraded_peak = float(np.mean(degraded.max_load[post]))
    inflation = degraded_peak / healthy_peak

    pic = dict(L=200, n_particles=20_000, steps=60, k=2, rho=0.9,
               cx=10, cy=10, num_pes=8, mapping="striped",
               lb_every=lb_every, seed=0, sharded_replay=True)
    pic_mesh = replay_shard._resolve_mesh(
        None, None, (pic["n_particles"], pic["num_pes"]))
    pic_D = int(np.prod(pic_mesh.devices.shape))
    pic_fs = rz.FaultSchedule(events=((20, pic_D // 2, "die"),))
    pic_ref = driver.run(driver.PICConfig(strategy="none", **pic))
    pic_dead = driver.run(driver.PICConfig(
        strategy="diff-comm", strategy_kwargs=dict(k=4), faults=pic_fs,
        **pic))
    pic_conserved = bool(
        np.array_equal(pic_dead.final_x, pic_ref.final_x)
        and np.array_equal(pic_dead.final_y, pic_ref.final_y))

    out["resilience"] = dict(
        num_shards=D,
        fault_step=fault_step,
        dead_shard=dead_shard,
        evacuated=evacuated,
        finite=finite,
        plans_rejected=rejected,
        healthy_peak_load=healthy_peak,
        degraded_peak_load=degraded_peak,
        peak_inflation=inflation,
        degrade_bound=DEGRADE_BOUND,
        pic_num_shards=pic_D,
        pic_particles_conserved=pic_conserved,
        pic_deferred_final=float(np.asarray(pic_dead.deferred)[-1])
        if pic_dead.deferred is not None else 0.0,
    )
    print(f"\nresilience: shard {dead_shard}/{D} dies at step "
          f"{fault_step} of {steps}")
    print(table(
        ["gate", "value", "pass"],
        [["evacuated (0 objects on dead nodes)", str(evacuated), evacuated],
         ["finite metrics", str(finite), finite],
         ["plans rejected", f"{rejected:.0f}", rejected == 0.0],
         ["post-fault peak inflation",
          f"{inflation:.2f}x (bound {DEGRADE_BOUND}x)",
          inflation < DEGRADE_BOUND],
         ["PIC particles conserved (dead shard)", str(pic_conserved),
          pic_conserved]]))
    assert finite, "degraded replay produced non-finite metrics"
    assert evacuated, \
        f"dead shard {dead_shard} still owns objects after the run"
    assert rejected == 0.0, \
        f"{rejected:.0f} engine plans failed validate_plan on a live mesh"
    assert inflation < DEGRADE_BOUND, \
        f"post-fault peak load {inflation:.2f}x exceeds {DEGRADE_BOUND}x"
    assert pic_conserved, "PIC fault run lost or corrupted particles"


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/replay_shard_bench.py", repeats=REPEATS,
        **out)


def run():
    import jax

    from benchmarks.common import save_result

    out = {"devices": len(jax.devices()),
           "backend": jax.default_backend(),
           # wall numbers on a forced CPU mesh measure virtual-device
           # overhead, not distributed planning time — flagged so the
           # perf trajectory never reads them as a regression
           "emulated_mesh": "xla_force_host_platform_device_count"
                            in os.environ.get("XLA_FLAGS", "")}
    _bench_scenarios(out)
    _bench_pic(out)
    _bench_resilience(out)

    path = save_result("replay_shard_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    return out


if __name__ == "__main__":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()
    run()
