"""LBEngine throughput: eager host-loop replay vs the scan-compiled
planning pipeline (core/engine.py + sim/simulator.py + pic/driver.py).

Headline measurement (the repo's acceptance gate for the device-resident
engine): replaying the `stencil-wave` scenario with `diff-comm` at P=64
nodes, K=8 neighbors over 200 steps on CPU, the scanned path must be
≥ 5× faster than the eager host loop and produce the identical plan
trajectory.  Also reports per-scenario scanned steps/sec and a PIC-driver
comparison (device-resident chunked scan vs legacy host loop).

  PYTHONPATH=src python benchmarks/engine_bench.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_result, table
from repro.pic import driver
from repro.sim import scenarios, simulator


def _series(problem, evolve, *, scan, steps, lb_every, strategy, kw):
    t0 = time.perf_counter()
    res = simulator.run_series(
        problem, evolve, steps=steps, lb_every=lb_every, strategy=strategy,
        strategy_kwargs=kw, scan=scan)
    return res, time.perf_counter() - t0


def run(P: int = 64, K: int = 8, steps: int = 200, grid: int = 32,
        lb_every: int = 10):
    out = {}

    # ---- headline: stencil-wave, diff-comm, P=64 K=8, 200 steps ---------
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=grid, num_nodes=P)
    kw = dict(k=K)
    common = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
                  kw=kw)

    # warm both paths: compile the scan, trace the eager per-stage jits
    _series(problem, evolve, scan=True, **common)
    _series(problem, evolve, scan=False,
            steps=lb_every + 2, lb_every=lb_every, strategy="diff-comm",
            kw=kw)

    res_scan, t_scan = _series(problem, evolve, scan=True, **common)
    res_eager, t_eager = _series(problem, evolve, scan=False, **common)

    parity = bool(
        np.allclose(res_eager.max_avg, res_scan.max_avg, rtol=1e-4)
        and np.allclose(res_eager.migrations, res_scan.migrations,
                        atol=1e-6))
    speedup = t_eager / max(t_scan, 1e-12)
    out["series"] = dict(
        P=P, K=K, steps=steps, grid=grid, lb_every=lb_every,
        eager_seconds=t_eager, scanned_seconds=t_scan,
        eager_steps_per_sec=steps / t_eager,
        scanned_steps_per_sec=steps / t_scan,
        speedup=speedup, parity=parity,
    )
    print(f"run_series diff-comm  P={P} K={K} grid={grid}² steps={steps}")
    print(table(
        ["path", "seconds", "steps/sec"],
        [["eager host loop", f"{t_eager:.3f}", f"{steps / t_eager:.1f}"],
         ["scanned", f"{t_scan:.4f}", f"{steps / t_scan:.1f}"],
         ["speedup", f"{speedup:.1f}x", ""]]))
    print(f"plan-trajectory parity (max/avg + migrations): {parity}")

    # ---- per-scenario scanned throughput --------------------------------
    small = {
        "stencil-wave": dict(grid=16, num_nodes=16),
        "pic-geometric": dict(cx=8, cy=8, num_pes=8, n_particles=10_000.0),
        "adversarial-hotspot": dict(grid=16, num_nodes=16),
        "bimodal-churn": dict(grid=16, num_nodes=16),
    }
    rows = []
    out["scenarios"] = {}
    for name in scenarios.available():
        prob, ev = scenarios.get(name).instantiate(**small.get(name, {}))
        c = dict(steps=100, lb_every=5, strategy="diff-comm", kw=dict(k=4))
        _series(prob, ev, scan=True, **c)                     # compile
        r, t = _series(prob, ev, scan=True, **c)
        rows.append([name, f"{100 / t:.0f}", f"{r.max_avg.mean():.3f}",
                     f"{r.migrations[r.migrations > 0].mean() if (r.migrations > 0).any() else 0:.3f}"])
        out["scenarios"][name] = dict(
            steps_per_sec=100 / t, mean_max_avg=float(r.max_avg.mean()))
    print("\nscanned replay, diff-comm k=4, 100 steps")
    print(table(["scenario", "steps/sec", "mean max/avg", "migr/LB"], rows))

    # ---- PIC driver: device-resident chunked scan vs host loop ----------
    base = dict(L=200, n_particles=20_000, steps=60, k=2, rho=0.9, cx=10,
                cy=10, num_pes=8, mapping="striped", lb_every=10,
                strategy="diff-comm", strategy_kwargs=dict(k=4))
    driver.run(driver.PICConfig(scan=True, **base))           # compile
    r_s = driver.run(driver.PICConfig(scan=True, **base))
    r_h = driver.run(driver.PICConfig(scan=False, **base))
    pic_speedup = r_h.wall_seconds / max(r_s.wall_seconds, 1e-12)
    out["pic"] = dict(
        host_seconds=r_h.wall_seconds, scanned_seconds=r_s.wall_seconds,
        speedup=pic_speedup,
        parity=bool(np.allclose(r_h.max_avg, r_s.max_avg, rtol=1e-4)),
    )
    print(f"\nPIC driver 20k particles, 60 steps: host {r_h.wall_seconds:.3f}s"
          f"  scanned {r_s.wall_seconds:.4f}s  ({pic_speedup:.1f}x)")

    path = save_result("engine_bench", out)
    print(f"\nsaved {path}")
    assert parity, "scanned plan must equal the eager plan"
    assert speedup >= 5.0, \
        f"scanned path must be >=5x the eager host loop, got {speedup:.1f}x"
    return out


if __name__ == "__main__":
    run()
