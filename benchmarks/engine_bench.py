"""LBEngine throughput: eager host-loop replay vs the scan-compiled
planning pipeline vs the batched (vmapped) multi-scenario path
(core/engine.py + sim/simulator.py + pic/driver.py).

Headline measurements (the repo's acceptance gates for the device-resident
engine, each the **median of 3 warm repeats**):

  * replaying the `stencil-wave` scenario with `diff-comm` at P=64 nodes,
    K=8 neighbors over 200 steps on CPU, the scanned path must be ≥ 5×
    faster than the eager host loop with an identical plan trajectory;
  * replaying B=16 scenario instances (every registered scenario at a
    common shape, `scenarios.batch_instances`) in one vmapped scan must be
    ≥ 4× faster than the per-scenario Python loop over scanned replays on
    **end-to-end suite time** (trace+compile+run — the loop compiles 16
    runners, the batch one), again with identical per-lane trajectories;
    warm run-only times are reported alongside.

Also reports per-scenario scanned steps/sec and a PIC-driver comparison
(device-resident chunked scan vs legacy host loop).  Results are written
twice: `artifacts/bench/engine_bench.json` (legacy location) and the
stable-schema `BENCH_engine.json` at the repo root (the perf-trajectory
artifact CI uploads — see `SCHEMA` below; keys are append-only).

  PYTHONPATH=src:. python benchmarks/engine_bench.py
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import save_result, table, timeit_median
from repro.pic import driver
from repro.sim import scenarios, simulator

SCHEMA = "engine-bench/v2"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")


def _series(problem, evolve, *, scan, steps, lb_every, strategy, kw):
    t0 = time.perf_counter()
    res = simulator.run_series(
        problem, evolve, steps=steps, lb_every=lb_every, strategy=strategy,
        strategy_kwargs=kw, scan=scan)
    return res, time.perf_counter() - t0


def _bench_series(P, K, steps, grid, lb_every, out):
    """Headline: stencil-wave, diff-comm, scanned vs eager host loop."""
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=grid, num_nodes=P)
    kw = dict(k=K)
    common = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
                  kw=kw)

    # warm both paths: compile the scan, trace the eager per-stage jits
    _series(problem, evolve, scan=True, **common)
    _series(problem, evolve, scan=False,
            steps=lb_every + 2, lb_every=lb_every, strategy="diff-comm",
            kw=kw)

    res_scan, t_scan = timeit_median(
        lambda: _series(problem, evolve, scan=True, **common)[0],
        repeat=REPEATS)
    res_eager, t_eager = timeit_median(
        lambda: _series(problem, evolve, scan=False, **common)[0],
        repeat=REPEATS)

    parity = bool(
        np.allclose(res_eager.max_avg, res_scan.max_avg, rtol=1e-4)
        and np.allclose(res_eager.migrations, res_scan.migrations,
                        atol=1e-6))
    speedup = t_eager / max(t_scan, 1e-12)
    out["series"] = dict(
        P=P, K=K, steps=steps, grid=grid, lb_every=lb_every,
        repeats=REPEATS,
        eager_seconds=t_eager, scanned_seconds=t_scan,
        eager_steps_per_sec=steps / t_eager,
        scanned_steps_per_sec=steps / t_scan,
        speedup=speedup, parity=parity,
    )
    print(f"run_series diff-comm  P={P} K={K} grid={grid}² steps={steps} "
          f"(median of {REPEATS})")
    print(table(
        ["path", "seconds", "steps/sec"],
        [["eager host loop", f"{t_eager:.3f}", f"{steps / t_eager:.1f}"],
         ["scanned", f"{t_scan:.4f}", f"{steps / t_scan:.1f}"],
         ["speedup", f"{speedup:.1f}x", ""]]))
    print(f"plan-trajectory parity (max/avg + migrations): {parity}")
    return speedup, parity


def _bench_batch(out, *, batch=16, steps=100, lb_every=5, k=4):
    """B scenario instances: one vmapped scan vs per-scenario Python loop.

    The gated number is the **end-to-end suite time** — trace + compile +
    run from a cold replay-runner cache, the cost every fresh process
    (CI, a parameter sweep, a notebook) pays to replay the scenario suite.
    The per-scenario loop compiles B runners; the batched path compiles
    exactly one vmapped executable — that is the structural win.  Warm
    run-only times are reported alongside for transparency: on CPU at
    these small shapes a fully-compiled per-scenario loop is already
    single-dispatch-per-lane, so the warm paths are roughly at par (the
    batch pays lockstep vmapped while_loops; the loop pays B dispatches).
    """
    inst = scenarios.batch_instances(batch)
    kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
              strategy_kwargs=dict(k=k))

    def loop():
        return [simulator.run_series(p, ev, scan=True, **kw)
                for _, p, ev in inst]

    def batched():
        return simulator.run_series_batch(inst, **kw)

    def cold(fn):
        simulator._batched_runner.cache_clear()
        simulator._scanned_runner.cache_clear()
        return fn()

    # warm the shared engine/plan caches once so both paths start equal,
    # then measure end-to-end suite time with cold replay-runner caches
    bres = batched()
    singles = loop()
    bres, t_batch = timeit_median(lambda: cold(batched), repeat=REPEATS)
    singles, t_loop = timeit_median(lambda: cold(loop), repeat=REPEATS)
    _, t_batch_warm = timeit_median(batched, repeat=REPEATS)
    _, t_loop_warm = timeit_median(loop, repeat=REPEATS)

    parity = all(
        np.allclose(s.max_avg, b.max_avg, rtol=1e-4)
        and np.allclose(s.migrations, b.migrations, atol=1e-6)
        for s, b in zip(singles, bres.series))
    speedup = t_loop / max(t_batch, 1e-12)
    out["batch"] = dict(
        batch=batch, steps=steps, lb_every=lb_every, k=k, repeats=REPEATS,
        scenarios=[n for n, _, _ in inst],
        loop_seconds=t_loop, batched_seconds=t_batch,
        loop_warm_seconds=t_loop_warm, batched_warm_seconds=t_batch_warm,
        warm_speedup=t_loop_warm / max(t_batch_warm, 1e-12),
        loop_lane_steps_per_sec=batch * steps / t_loop,
        batched_lane_steps_per_sec=batch * steps / t_batch,
        speedup=speedup, parity=parity,
    )
    print(f"\nbatched replay, {batch} scenario lanes × {steps} steps, "
          f"end-to-end suite time incl. compile (median of {REPEATS})")
    print(table(
        ["path", "suite seconds", "warm seconds"],
        [["per-scenario loop", f"{t_loop:.2f}", f"{t_loop_warm:.3f}"],
         ["vmapped batch", f"{t_batch:.2f}", f"{t_batch_warm:.3f}"],
         ["speedup", f"{speedup:.1f}x",
          f"{t_loop_warm / max(t_batch_warm, 1e-12):.1f}x"]]))
    print(f"per-lane trajectory parity: {parity}")
    return speedup, parity


def _bench_scenarios(out):
    """Per-scenario scanned throughput."""
    small = {
        "stencil-wave": dict(grid=16, num_nodes=16),
        "pic-geometric": dict(cx=8, cy=8, num_pes=8, n_particles=10_000.0),
        "adversarial-hotspot": dict(grid=16, num_nodes=16),
        "bimodal-churn": dict(grid=16, num_nodes=16),
    }
    rows = []
    out["scenarios"] = {}
    for name in scenarios.available():
        prob, ev = scenarios.get(name).instantiate(**small.get(name, {}))
        c = dict(steps=100, lb_every=5, strategy="diff-comm", kw=dict(k=4))
        _series(prob, ev, scan=True, **c)                     # compile
        r, t = timeit_median(
            lambda prob=prob, ev=ev: _series(prob, ev, scan=True, **c)[0],
            repeat=REPEATS)
        rows.append([name, f"{100 / t:.0f}", f"{r.max_avg.mean():.3f}",
                     f"{r.migrations[r.migrations > 0].mean() if (r.migrations > 0).any() else 0:.3f}"])
        out["scenarios"][name] = dict(
            steps_per_sec=100 / t, mean_max_avg=float(r.max_avg.mean()))
    print(f"\nscanned replay, diff-comm k=4, 100 steps (median of {REPEATS})")
    print(table(["scenario", "steps/sec", "mean max/avg", "migr/LB"], rows))


def _bench_pic(out):
    """PIC driver: device-resident chunked scan vs host loop."""
    base = dict(L=200, n_particles=20_000, steps=60, k=2, rho=0.9, cx=10,
                cy=10, num_pes=8, mapping="striped", lb_every=10,
                strategy="diff-comm", strategy_kwargs=dict(k=4))
    driver.run(driver.PICConfig(scan=True, **base))           # compile
    r_s, t_s = timeit_median(
        lambda: driver.run(driver.PICConfig(scan=True, **base)),
        repeat=REPEATS)
    r_h, t_h = timeit_median(
        lambda: driver.run(driver.PICConfig(scan=False, **base)),
        repeat=REPEATS)
    pic_speedup = t_h / max(t_s, 1e-12)
    out["pic"] = dict(
        host_seconds=t_h, scanned_seconds=t_s, repeats=REPEATS,
        speedup=pic_speedup,
        parity=bool(np.allclose(r_h.max_avg, r_s.max_avg, rtol=1e-4)),
    )
    print(f"\nPIC driver 20k particles, 60 steps: host {t_h:.3f}s"
          f"  scanned {t_s:.4f}s  ({pic_speedup:.1f}x)")


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/engine_bench.py", repeats=REPEATS, **out)


def run(P: int = 64, K: int = 8, steps: int = 200, grid: int = 32,
        lb_every: int = 10):
    out = {}
    speedup, parity = _bench_series(P, K, steps, grid, lb_every, out)
    batch_speedup, batch_parity = _bench_batch(out)
    _bench_scenarios(out)
    _bench_pic(out)

    path = save_result("engine_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    assert parity, "scanned plan must equal the eager plan"
    assert speedup >= 5.0, \
        f"scanned path must be >=5x the eager host loop, got {speedup:.1f}x"
    assert batch_parity, "batched lanes must match per-scenario replays"
    assert batch_speedup >= 4.0, \
        f"batched path must be >=4x the per-scenario loop, " \
        f"got {batch_speedup:.1f}x"
    return out


if __name__ == "__main__":
    run()
