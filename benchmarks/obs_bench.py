"""Telemetry overhead gate: the StepRecord ring must be near-free.

The scan-carried telemetry (``repro.obs.telemetry``) rides inside the
compiled replay loops, so its cost is a pure device-side increment: one
(ring, F) ``dynamic_update_slice`` plus the load statistics per step.
This bench measures that cost on the two replay paths the observability
issue gates on — the scanned sim replay and the scanned serving replay —
as the warm-run slowdown of ``level="counters"`` / ``level="full"``
against ``level="off"`` (bit-for-bit the pre-telemetry program).

Gates (per path, best of REPEATS warm runs, levels interleaved round-robin
so thermal/scheduler drift hits all three equally):

  * ``counters`` ≤ 5% slowdown vs ``off``
  * ``full``    ≤ 15% slowdown vs ``off``

Best-of-N is the gating statistic here (not the usual median): the
overhead of a fixed compiled program is a lower-bound property, and on a
shared CPU runner the min is the estimator least contaminated by noise
that would otherwise dwarf a ≤5% effect.

Results are written twice: ``artifacts/bench/obs_bench.json`` and the
stable-schema ``BENCH_obs.json`` at the repo root (CI uploads both).

  PYTHONPATH=src:. python benchmarks/obs_bench.py
"""
from __future__ import annotations

import os

import time

from benchmarks.common import save_result, table, write_bench_json

SCHEMA = "obs-bench/v1"
REPEATS = 9
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_obs.json")

#: (path, level) → max tolerated warm-run slowdown vs level="off"
GATES = {"counters": 0.05, "full": 0.15}
LEVELS = ("off", "counters", "full")


def _time_levels(run):
    """Best-of-REPEATS warm seconds per level, interleaved round-robin."""
    for level in LEVELS:
        run(level)                                   # compile all first
    best = {level: float("inf") for level in LEVELS}
    for _ in range(REPEATS):
        for level in LEVELS:
            t0 = time.perf_counter()
            run(level)
            best[level] = min(best[level], time.perf_counter() - t0)
    return best


def _bench_sim(out, *, P=64, K=8, grid=32, steps=200, lb_every=10):
    from repro.sim import scenarios, simulator

    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=grid, num_nodes=P)
    kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
              strategy_kwargs=dict(k=K), scan=True)

    def run(level):
        return simulator.run_series(problem, evolve, telemetry=level,
                                    **kw)

    _report("sim-scan", out, _time_levels(run),
            dict(P=P, K=K, grid=grid, steps=steps, lb_every=lb_every))


def _bench_serve(out, *, sessions=512, replicas=8, ticks=400, lb_every=10):
    from repro.serve import replay as sr

    w = sr.ServeWorkload(num_sessions=sessions, num_replicas=replicas)
    kw = dict(steps=ticks, lb_every=lb_every,
              strategy="diff-comm+predictive")

    def run(level):
        return sr.run_serve_replay(w, telemetry=level, **kw)

    _report("serve-scan", out, _time_levels(run),
            dict(sessions=sessions, replicas=replicas, ticks=ticks,
                 lb_every=lb_every))


def _report(name, out, times, config):
    t_off = max(times["off"], 1e-12)
    overhead = {lvl: times[lvl] / t_off - 1.0 for lvl in GATES}
    out[name] = dict(
        config=config, repeats=REPEATS,
        seconds={lvl: times[lvl] for lvl in LEVELS},
        overhead=overhead,
        gates=dict(GATES),
    )
    print(f"\n{name} telemetry overhead "
          f"(best of {REPEATS} interleaved warm runs)")
    print(table(
        ["level", "seconds", "overhead", "gate"],
        [["off", f"{times['off']:.4f}", "-", "-"]]
        + [[lvl, f"{times[lvl]:.4f}", f"{overhead[lvl]*100:+.1f}%",
            f"<={GATES[lvl]*100:.0f}%"] for lvl in GATES]))


def run():
    out = {}
    _bench_sim(out)
    _bench_serve(out)

    path = save_result("obs_bench", out)
    bench_path = write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/obs_bench.py", repeats=REPEATS, **out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    for name, res in out.items():
        for lvl, bound in GATES.items():
            got = res["overhead"][lvl]
            assert got <= bound, (
                f"{name}: telemetry level={lvl!r} costs {got*100:.1f}% "
                f"(gate {bound*100:.0f}%) — the ring write must stay "
                "near-free")
    return out


if __name__ == "__main__":
    run()
