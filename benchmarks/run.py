"""Benchmark orchestrator — one entry per paper table/figure + the
beyond-paper framework benchmarks.

  python -m benchmarks.run            # everything (≈ a few minutes on CPU)
  python -m benchmarks.run fig4 roofline    # a subset
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (engine_bench, ep_balance_bench, fig2_stencil,
                        fig4_pic_lb, fig5_scaling, kernel_bench,
                        replay_shard_bench, roofline, runtime_bench,
                        table1_neighbor_count, table2_strategies)

ALL = {
    "fig2": fig2_stencil.run,
    "table1": table1_neighbor_count.run,
    "table2": table2_strategies.run,
    "fig4": fig4_pic_lb.run,
    "fig5": fig5_scaling.run,
    "engine": engine_bench.run,
    "runtime": runtime_bench.run,
    "replay": replay_shard_bench.run,
    "ep_balance": ep_balance_bench.run,
    "kernels": kernel_bench.run,
    "roofline": roofline.run,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    failures = []
    t0 = time.time()
    for name in names:
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        try:
            t1 = time.time()
            ALL[name]()
            print(f"-- {name} OK ({time.time()-t1:.1f}s)", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\n{'='*72}")
    print(f"benchmarks done in {time.time()-t0:.0f}s; "
          f"{len(names)-len(failures)}/{len(names)} OK"
          + (f"; FAILED: {failures}" if failures else ""))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
