"""Live MoE expert rebalancing benchmark: diffusion + predictive vs
greedy + fixed cadence.

Replays skewed top-k routing traffic through the expert-placement
runtime (``train/ep_runtime.py`` — device-resident routing statistics,
trigger decision, and **executed** expert-weight exchange inside one
``lax.scan``) and prices what an MoE training operator actually pays:
step time lost to expert-load imbalance (the slowest EP rank gates the
step) and the expert-weight bytes rebalancing moves over the wire.  The
headline gate: the paper's comm-aware diffusion planner with the
measured-byte predictive trigger must beat the rebalance-everything
greedy baseline on a fixed cadence **on both axes at once** — more
tokens/s recovered AND less weight traffic.

Tokens/s come from ``RuntimeCostModel.step_seconds`` applied to each
replay's per-step records (slowest-rank tokens × t_load + executed
weight bytes × t_byte + fixed fire overhead) — the same model the
predictive trigger amortizes against, so the gate and the gate's own
decision rule price bytes identically.

The bench also asserts the runtime's core contract in passing: the
scanned replay and the eager host loop must agree **bit-for-bit**
(fires, placements, moved bytes) before any number is reported.

Results are written twice: ``artifacts/bench/moe_bench.json`` (legacy
location) and the stable-schema ``BENCH_moe.json`` at the repo root
(schema ``moe-bench/v1``; keys are append-only; committed +
CI-uploaded).

  PYTHONPATH=src:. python benchmarks/moe_bench.py
"""
from __future__ import annotations

import json
import os

SCHEMA = "moe-bench/v2"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_moe.json")

#: per-token-load second — normalizes the slowest rank's EMA token count
#: into step seconds
T_LOAD = 1e-3
#: seconds per expert-weight byte on the wire: priced so a greedy
#: full-shuffle fire (~2e5 B at the bench scale) costs the same order as
#: the imbalance-time a drift epoch accumulates (~5 load-seconds) — the
#: regime where the measured predictive gate has a real decision to make
T_BYTE = 4e-5
#: fixed per-fire cost (planning + barrier), seconds
LB_OVERHEAD = 0.05


def _cost():
    from repro.runtime.cost import RuntimeCostModel

    return RuntimeCostModel(t_load=T_LOAD, t_byte=T_BYTE,
                            lb_overhead=LB_OVERHEAD)


def _policies():
    from repro.runtime.triggers import PredictiveTrigger

    return {
        "diff-comm+predictive": dict(
            strategy="diff-comm",
            trigger=PredictiveTrigger(cost=_cost())),
        "greedy+every": dict(strategy="greedy", trigger="every"),
    }


def _tokens_per_sec(workload, res):
    """Modeled training throughput of one replay: routed tokens over the
    summed per-step seconds (slowest rank + executed weight traffic)."""
    import numpy as np

    cost = _cost()
    # max_avg is the post-LB max/avg rank-load ratio over the EMA token
    # counts; the EMA total converges to one step's routed load, so the
    # slowest rank processes ~ max_avg x (T x k / R) tokens per step
    ideal = workload.tokens_per_step * workload.top_k / workload.num_ranks
    max_load = res.max_avg * ideal
    secs = np.asarray(cost.step_seconds(
        max_load.astype(np.float32),
        (res.moved_bytes / cost.bytes_per_load).astype(np.float32),
        res.lb_fired.astype(np.float32)))
    total = float(secs.sum())
    steps = len(res.max_avg)
    return workload.tokens_per_step * steps / max(total, 1e-12), total


def _replay_one(workload, steps, policy):
    from benchmarks.common import timeit_median
    from repro.train import ep_runtime as epr

    res, wall = timeit_median(
        lambda: epr.run_ep_replay(workload, steps=steps, lb_every=10,
                                  **policy),
        repeat=REPEATS)
    toks, modeled = _tokens_per_sec(workload, res)
    return dict(
        tokens_per_second=toks,
        modeled_seconds=modeled,
        mean_imbalance=float(res.max_avg.mean()),
        final_imbalance=float(res.max_avg[-8:].mean()),
        moved_weight_bytes=res.total_moved_bytes,
        moved_experts=float(res.moved_experts.sum()),
        rebalances=float(res.lb_fired.sum()),
        scanned=bool(res.scanned),
        wall_seconds=wall,
    )


def _assert_scan_host_parity(workload, steps):
    """The runtime's core contract, checked before anything is priced:
    the scanned and eager host replays are the same computation."""
    import numpy as np

    from repro.train import ep_runtime as epr

    kw = dict(steps=steps, strategy="diff-comm", lb_every=10)
    a = epr.run_ep_replay(workload, **kw)
    b = epr.run_ep_replay(workload, scan=False, **kw)
    assert a.scanned and not b.scanned
    for field in ("lb_fired", "max_avg", "moved_experts", "moved_bytes",
                  "final_placement", "final_slot_expert", "final_wsig"):
        np.testing.assert_array_equal(
            getattr(a, field), getattr(b, field),
            err_msg=f"scan<->host divergence in {field}")
    return float(a.lb_fired.sum())


def _bench_policies(out, *, steps=96):
    """The gated comparison on skewed drifting routing traffic."""
    from benchmarks.common import table
    from repro.train import ep_runtime as epr

    # fine-granularity regime (E/R = 16 experts per rank, mild Zipf):
    # the paper's diffusion moves load in single-expert quanta, so the
    # top expert must not dwarf the per-neighbor flow budgets — at
    # alpha=1 + a 7x hot boost one expert exceeds a whole rank's fair
    # share and *no* planner can balance by moving anything else
    synth = epr.RoutingWorkload(num_experts=128, num_ranks=8,
                                tokens_per_step=4096, alpha=0.5,
                                hot_amp=2.0, drift_period=16,
                                trace_len=64, seed=0)
    trace = epr.record_routing(
        epr.RoutingWorkload(num_experts=128, num_ranks=8,
                            tokens_per_step=2048, alpha=0.5,
                            hot_amp=2.5, drift_period=12,
                            trace_len=48, seed=3),
        steps=steps)
    out["parity_fires"] = _assert_scan_host_parity(
        epr.RoutingWorkload(num_experts=32, num_ranks=8,
                            tokens_per_step=512, trace_len=24, seed=7),
        24)
    print(f"scan<->host parity OK ({out['parity_fires']:.0f} fires "
          "replayed bit-for-bit)")

    out["workloads"] = {}
    for wname, (w, T) in {"synthetic": (synth, steps),
                          "trace": (trace, steps)}.items():
        entry = dict(num_experts=int(w.num_experts),
                     num_ranks=int(w.num_ranks), steps=T, policies={})
        rows = []
        for pname, policy in _policies().items():
            r = _replay_one(w, T, policy)
            entry["policies"][pname] = r
            rows.append([pname, int(r["rebalances"]),
                         f"{r['tokens_per_second']:.0f}",
                         f"{r['mean_imbalance']:.3f}",
                         f"{r['moved_weight_bytes']:.0f}",
                         f"{r['wall_seconds']:.3f}"])
        diff = entry["policies"]["diff-comm+predictive"]
        base = entry["policies"]["greedy+every"]
        entry["gates"] = dict(
            tokens_per_sec_recovered=diff["tokens_per_second"]
            >= base["tokens_per_second"],
            moved_weight_no_more=diff["moved_weight_bytes"]
            <= base["moved_weight_bytes"],
        )
        out["workloads"][wname] = entry
        print(f"\n{wname}: E={w.num_experts} R={w.num_ranks} T={T} "
              f"(median of {REPEATS})")
        print(table(["policy", "fires", "tokens/s", "mean max/avg",
                     "moved W bytes", "wall s"], rows))
        assert entry["gates"]["tokens_per_sec_recovered"], (
            f"{wname}: diffusion+predictive "
            f"{diff['tokens_per_second']:.0f} tokens/s below greedy "
            f"{base['tokens_per_second']:.0f}")
        assert entry["gates"]["moved_weight_no_more"], (
            f"{wname}: diffusion+predictive moved "
            f"{diff['moved_weight_bytes']:.0f} weight bytes > greedy "
            f"{base['moved_weight_bytes']:.0f}")


def _bench_scale(out, *, num_experts=256, num_ranks=32, steps=48):
    """A production-shaped expert count through the scanned replay —
    wall reported, not gated (CPU CI measures XLA host throughput)."""
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.train import ep_runtime as epr

    w = epr.RoutingWorkload(num_experts=num_experts, num_ranks=num_ranks,
                            tokens_per_step=4096, alpha=0.5, hot_amp=2.0,
                            trace_len=48, seed=1)
    # fixed cadence: the scale entry measures replay throughput with
    # executed exchanges on every fire, so the fire count must not
    # depend on how a cost model prices this scale
    res, wall = timeit_median(
        lambda: epr.run_ep_replay(w, steps=steps, lb_every=8,
                                  strategy="diff-comm", trigger="every"),
        repeat=REPEATS)
    assert np.isfinite(res.max_avg).all()
    assert int(res.lb_fired.sum()) > 0 and res.total_moved_bytes > 0
    out["scale"] = dict(
        num_experts=num_experts,
        num_ranks=num_ranks,
        steps=steps,
        rebalances=float(res.lb_fired.sum()),
        moved_weight_bytes=res.total_moved_bytes,
        mean_imbalance=float(res.max_avg.mean()),
        wall_seconds=wall,
        steps_per_second=steps / max(wall, 1e-9),
    )
    print(f"\nscale: E={num_experts} R={num_ranks} T={steps} "
          f"(median of {REPEATS})")
    print(table(
        ["fires", "moved W bytes", "mean max/avg", "wall s", "steps/s"],
        [[int(res.lb_fired.sum()), f"{res.total_moved_bytes:.0f}",
          f"{out['scale']['mean_imbalance']:.3f}", f"{wall:.3f}",
          f"{out['scale']['steps_per_second']:.2f}"]]))


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/moe_bench.py", repeats=REPEATS,
        **out)


def run():
    import jax

    from benchmarks.common import save_result

    out = {"devices": len(jax.devices()),
           "backend": jax.default_backend(),
           "t_load": T_LOAD, "t_byte": T_BYTE,
           "lb_overhead": LB_OVERHEAD}
    _bench_policies(out)
    _bench_scale(out)

    path = save_result("moe_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    return out


if __name__ == "__main__":
    run()
