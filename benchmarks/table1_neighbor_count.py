"""Paper Table I: ring of processors, single 10× hotspot, K ∈ {1,2,4,8}.

Paper values:    K:        1      2      4      8
  max/avg load         4.9    1.7    1.3    1.1
  ext/int comm (MB)   .142   .151   .25    .26

Claims validated: (1) balance improves monotonically with K (the hotspot
can shed to more neighbors); (2) external/internal communication *rises*
with K (distant/no-comm neighbors accept load — §V.B)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.core import api, metrics
from repro.sim import stencil, synthetic

PAPER = {1: (4.9, 0.142), 2: (1.7, 0.151), 4: (1.3, 0.25), 8: (1.1, 0.26)}


def run(nx: int = 64, ny: int = 16, pes: int = 16, factor: float = 10.0):
    prob = stencil.stencil_2d(nx, ny, pes, mapping="ring")
    prob = synthetic.hotspot(prob, node=0, factor=factor)
    before = metrics.evaluate(prob)
    rows = []
    out = dict(before=before, cells={})
    for k in (1, 2, 4, 8):
        info = api.run_strategy("diff-comm", prob, k=k).info
        out["cells"][k] = info
        pm, pe = PAPER[k]
        rows.append([k, f"{info['max_avg_load']:.2f}", f"{pm}",
                     f"{info['ext_int_comm']:.3f}", f"{pe}",
                     f"{info['diffusion_iters']}"])
    print(f"Table I — ring, one {factor:.0f}x hotspot "
          f"(initial max/avg {before['max_avg_load']:.2f})")
    print(table(["K", "max/avg", "paper", "ext/int", "paper", "iters"],
                rows))
    ks = sorted(out["cells"])
    ma = [out["cells"][k]["max_avg_load"] for k in ks]
    ei = [out["cells"][k]["ext_int_comm"] for k in ks]
    assert all(a >= b - 0.05 for a, b in zip(ma, ma[1:])), "balance vs K"
    assert ei[-1] > ei[0], "locality degrades with K (paper §V.B)"
    save_result("table1_neighbor_count", out)
    return out


if __name__ == "__main__":
    run()
