"""Kernel micro-benchmarks: oracle wall time at simulator scale + the
structural (VMEM/roofline) accounting for the Pallas kernels.

Interpret-mode Pallas is Python-slow, so wall time is measured on the jnp
oracle (numerically identical); the Pallas path is validated for
correctness in tests/test_kernels.py and characterized here structurally:
bytes touched per sweep, VMEM working set per block, arithmetic intensity.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import save_result, table, timeit
from repro.core.virtual_lb import reference_sweep, reverse_slots


def diffusion_numbers(P: int, K: int, block_p: int = 512):
    bytes_hbm = (P * 4 * 2          # x, own read
                 + P * K * (4 + 1 + 4)   # nbr idx, mask, rev
                 + P * K * 4 * 2    # push write+read
                 + P * 4 * 2 + P * K * 4)
    flops = P * K * 6
    vmem = (P * 4 * 2 + block_p * K * (4 + 1 + 4) + block_p * K * 4)
    return dict(bytes=bytes_hbm, flops=flops, intensity=flops / bytes_hbm,
                vmem_block=vmem)


def run():
    rows = []
    out = {}
    for P, K in [(4096, 4), (65536, 8), (1_048_576, 8)]:
        rng = np.random.default_rng(0)
        cols = [(np.arange(P) + h) % P for h in range(1, K // 2 + 1)]
        cols += [(np.arange(P) - h) % P for h in range(1, K - len(cols) + 1)]
        nbr = jnp.asarray(np.stack(cols[:K], 1).astype(np.int32))
        mask = jnp.ones((P, K), bool)
        rev = reverse_slots(nbr, mask)
        x = jnp.asarray(rng.random(P).astype(np.float32))

        sweep = jax.jit(lambda x, own: reference_sweep(
            x, own, nbr, mask, rev, jnp.float32(1.0 / (K + 1)), True))
        sweep(x, x)[0].block_until_ready()            # compile
        _, sec = timeit(lambda: sweep(x, x)[0].block_until_ready())
        n = diffusion_numbers(P, K)
        tpu_est_us = n["bytes"] / 819e9 * 1e6         # HBM-bound estimate
        rows.append([f"P={P:>8} K={K}", f"{sec*1e3:.2f}ms",
                     f"{n['bytes']/2**20:.1f}", f"{n['intensity']:.2f}",
                     f"{n['vmem_block']/2**10:.0f}KiB", f"{tpu_est_us:.0f}us"])
        out[f"P{P}_K{K}"] = dict(cpu_oracle_s=sec, **n,
                                 tpu_hbm_bound_us=tpu_est_us)
    print("diffusion sweep (the balancer's hot loop at simulator scale)")
    print(table(["config", "cpu oracle", "MiB/sweep", "flop/byte",
                 "VMEM/blk", "TPU est"], rows))
    save_result("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
