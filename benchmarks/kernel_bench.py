"""Kernel micro-benchmarks: oracle wall time at simulator scale + the
structural (VMEM/roofline) accounting for the Pallas kernels.

Interpret-mode Pallas is Python-slow, so wall time is measured on the jnp
oracle (numerically identical); the Pallas path is validated for
correctness in tests/test_kernels.py and characterized here structurally:
bytes touched per sweep, VMEM working set per block, arithmetic intensity.

The **migrate** section times the manifest build+apply pipeline both ways
— stable-argsort vs the sort-free counting scatter — at replay scale
(n ∈ {2^16, 2^20}, the PIC loops' P = 8, median-of-3) and gates on the
sort-free path being no slower at n = 2^20 (the PR's reason to exist).

Results are written twice: ``artifacts/bench/kernel_bench.json`` (legacy
location) and the stable-schema ``BENCH_kernels.json`` at the repo root
(schema ``kernel-bench/v1``; keys are append-only; committed +
CI-uploaded so the kernel perf trajectory is attributable).

  PYTHONPATH=src:. python benchmarks/kernel_bench.py
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import save_result, table, timeit, timeit_median
from repro.core.virtual_lb import reference_sweep, reverse_slots
from repro.kernels.migrate import ops as migrate_ops
from repro.runtime import migrate as rt_migrate

SCHEMA = "kernel-bench/v2"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")

#: replay-loop shape: the PIC drivers and the sharded replay runtime all
#: exchange over P = 8 nodes; 3 payload arrays stand in for the
#: (position, velocity, id) slabs every consumer relocates together
MIGRATE_P = 8
MIGRATE_PAYLOADS = 3


def diffusion_numbers(P: int, K: int, block_p: int = 512):
    bytes_hbm = (P * 4 * 2          # x, own read
                 + P * K * (4 + 1 + 4)   # nbr idx, mask, rev
                 + P * K * 4 * 2    # push write+read
                 + P * 4 * 2 + P * K * 4)
    flops = P * K * 6
    vmem = (P * 4 * 2 + block_p * K * (4 + 1 + 4) + block_p * K * 4)
    return dict(bytes=bytes_hbm, flops=flops, intensity=flops / bytes_hbm,
                vmem_block=vmem)


def _bench_diffusion(out):
    rows = []
    out["diffusion"] = {}
    for P, K in [(4096, 4), (65536, 8), (1_048_576, 8)]:
        rng = np.random.default_rng(0)
        cols = [(np.arange(P) + h) % P for h in range(1, K // 2 + 1)]
        cols += [(np.arange(P) - h) % P for h in range(1, K - len(cols) + 1)]
        nbr = jnp.asarray(np.stack(cols[:K], 1).astype(np.int32))
        mask = jnp.ones((P, K), bool)
        rev = reverse_slots(nbr, mask)
        x = jnp.asarray(rng.random(P).astype(np.float32))

        sweep = jax.jit(lambda x, own: reference_sweep(
            x, own, nbr, mask, rev, jnp.float32(1.0 / (K + 1)), True))
        sweep(x, x)[0].block_until_ready()            # compile
        _, sec = timeit(lambda: sweep(x, x)[0].block_until_ready())
        n = diffusion_numbers(P, K)
        tpu_est_us = n["bytes"] / 819e9 * 1e6         # HBM-bound estimate
        rows.append([f"P={P:>8} K={K}", f"{sec*1e3:.2f}ms",
                     f"{n['bytes']/2**20:.1f}", f"{n['intensity']:.2f}",
                     f"{n['vmem_block']/2**10:.0f}KiB", f"{tpu_est_us:.0f}us"])
        out["diffusion"][f"P{P}_K{K}"] = dict(cpu_oracle_s=sec, **n,
                                              tpu_hbm_bound_us=tpu_est_us)
    print("diffusion sweep (the balancer's hot loop at simulator scale)")
    print(table(["config", "cpu oracle", "MiB/sweep", "flop/byte",
                 "VMEM/blk", "TPU est"], rows))


def _migrate_fns(n, P, k):
    """Jitted sort vs scatter manifest build+apply closures + inputs."""
    rng = np.random.default_rng(n)
    oo = jnp.asarray(rng.integers(0, P, n), jnp.int32)
    on = jnp.asarray(rng.integers(0, P, n), jnp.int32)
    arrs = tuple(jnp.asarray(rng.random(n), jnp.float32) for _ in range(k))

    def make(method):
        @jax.jit
        def fn(oo, on, arrs):
            outs, man = rt_migrate.build_and_apply(
                oo, on, arrs, num_nodes=P, method=method)
            return outs, man.moved_count
        return fn

    return make("sort"), make("scatter"), (oo, on, arrs)


def _bench_migrate(out):
    rows = []
    out["migrate"] = dict(P=MIGRATE_P, payload_arrays=MIGRATE_PAYLOADS,
                          impl=migrate_ops.scatter_impl(1 << 20, MIGRATE_P))
    for n in (1 << 16, 1 << 20):
        f_sort, f_scatter, args = _migrate_fns(n, MIGRATE_P,
                                               MIGRATE_PAYLOADS)
        want, _ = f_sort(*args)
        got, _ = f_scatter(*args)
        for a, b in zip(want, got):      # layout contract before timing
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        def run(fn, args=args):
            outs, moved = fn(*args)
            jax.block_until_ready(outs)

        run(f_sort), run(f_scatter)                   # compile
        _, sort_s = timeit_median(run, f_sort, repeat=REPEATS)
        _, scat_s = timeit_median(run, f_scatter, repeat=REPEATS)
        speedup = sort_s / scat_s
        out["migrate"][f"n{n}"] = dict(
            sort_s=sort_s, scatter_s=scat_s, speedup=speedup)
        rows.append([f"n=2^{n.bit_length() - 1}", f"{sort_s*1e3:.1f}ms",
                     f"{scat_s*1e3:.1f}ms", f"{speedup:.2f}x"])
    print(f"\nmigrate manifest build+apply (P={MIGRATE_P}, "
          f"{MIGRATE_PAYLOADS} payload arrays, median of {REPEATS})")
    print(table(["size", "argsort", "counting scatter", "speedup"], rows))


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/kernel_bench.py", repeats=REPEATS,
        backend=jax.default_backend(),
        **out)


def run():
    out = {}
    _bench_diffusion(out)
    _bench_migrate(out)

    path = save_result("kernel_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    big = out["migrate"][f"n{1 << 20}"]
    assert big["speedup"] >= 1.0, \
        "sort-free manifest build+apply must be no slower than the " \
        f"argsort path at n=2^20: {big['scatter_s']:.3f}s vs " \
        f"{big['sort_s']:.3f}s"
    return out


if __name__ == "__main__":
    run()
