"""Beyond-paper benchmark: the paper's balancer as MoE expert placement.

Simulates deepseek-style routing drift (a skewed expert popularity that
shifts over time) and compares three placement policies on (a) max/avg
token load across EP ranks, (b) expert-migration traffic, (c) cross-rank
co-activation (token duplication proxy — the ext/int analogue):

  static      — never move experts (the default in most MoE systems)
  greedy      — re-place all experts by load every period (GreedyLB analog)
  diff-comm   — the paper's three-stage balancer on the expert graph
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import save_result, table, write_bench_json
from repro.distributed import ep_balance as eb

SCHEMA = "ep-balance-bench/v1"
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_ep_balance.json")


def _route(E, T, k, phase, rng):
    """Skewed routing with drifting hotspot: popularity ∝ zipf rotated by
    ``phase``."""
    ranks = (np.arange(E) - phase) % E
    p = 1.0 / (1 + ranks.astype(np.float64)) ** 1.2
    p /= p.sum()
    flat = rng.choice(E, size=T * k, p=p)
    return flat.reshape(T, k)


def _ext_coact(stats: eb.ExpertStats, placement) -> float:
    E = stats.num_experts
    same = stats.coact * (placement[:, None] == placement[None, :])
    tot = stats.coact.sum()
    return float((tot - same.sum()) / max(same.sum(), 1e-9))


def run(E: int = 64, R: int = 8, periods: int = 12, T: int = 4096,
        k: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    bytes_per_expert = 3 * 4096 * 14336 * 2 / 2**20   # MiB, llama-ish

    results = {}
    for policy in ["static", "greedy", "diff-comm"]:
        stats = eb.ExpertStats(E, ema=0.7)
        placement = (np.arange(E) * R // E).astype(np.int32)
        ma, moved, ext = [], 0, []
        for t in range(periods):
            ids = _route(E, T, k, phase=t * 3, rng=rng)
            stats.update(ids)
            if policy != "static" and t % 2 == 1:
                new, info = eb.plan_placement(
                    stats, placement, R,
                    strategy="greedy" if policy == "greedy" else "diff-comm")
                moved += int((new != placement).sum())
                placement = new
            loads = np.bincount(ids.reshape(-1), minlength=E)
            rank_load = np.bincount(placement, weights=loads, minlength=R)
            ma.append(rank_load.max() / rank_load.mean())
            ext.append(_ext_coact(stats, placement))
        results[policy] = dict(
            mean_max_avg=float(np.mean(ma)),
            moved_experts=moved,
            migration_mib=moved * bytes_per_expert,
            mean_ext_coact=float(np.mean(ext)),
        )

    rows = [[p, f"{r['mean_max_avg']:.3f}", r["moved_experts"],
             f"{r['migration_mib']:.0f}", f"{r['mean_ext_coact']:.2f}"]
            for p, r in results.items()]
    print(f"EP balance — {E} experts / {R} ranks, drifting zipf routing")
    print(table(["policy", "max/avg", "moved", "migr MiB", "ext coact"],
                rows))
    assert results["diff-comm"]["mean_max_avg"] < results["static"]["mean_max_avg"]
    assert results["diff-comm"]["moved_experts"] <= results["greedy"]["moved_experts"]
    save_result("ep_balance", results)
    write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/ep_balance_bench.py",
        config=dict(E=E, R=R, periods=periods, T=T, k=k, seed=seed),
        policies=results)
    return results


if __name__ == "__main__":
    run()
