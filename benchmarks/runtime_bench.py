"""Online rebalancing runtime: fixed vs threshold vs predictive triggers.

Replays every registered scenario (sim/scenarios.py) under the three
trigger policies with the ``diff-comm`` planner and prices each
trajectory with ``runtime.cost.RuntimeCostModel`` — slowest-node compute
+ executed migration traffic + per-rebalance overhead.  The headline
acceptance gates (deterministic modeled time, not wall noise):

  * on ``bimodal-churn`` and ``adversarial-hotspot`` — the unpredictable-
    imbalance regimes the adaptive triggers exist for — both the
    threshold and the predictive policy must beat the fixed
    ``lb_every=10`` cadence on total modeled seconds;
  * the executed PIC migration must conserve the particle count exactly
    and report ``migrated_bytes`` from the executed exchange.

Replay wall time is reported as the median of 3 warm repeats.  Results
are written twice: ``artifacts/bench/runtime_bench.json`` (legacy
location) and the stable-schema ``BENCH_runtime.json`` at the repo root
(schema ``runtime-bench/v2``; keys are append-only — v2 adds the
``manifest_method`` the PIC exchange resolved to (sort vs sort-free
counting scatter), so the perf trajectory stays attributable across
manifest-kernel changes; committed + CI-uploaded).

  PYTHONPATH=src:. python benchmarks/runtime_bench.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import save_result, table, timeit_median
from repro.pic import driver
from repro.runtime import cost as rt_cost
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers
from repro.sim import scenarios, simulator

SCHEMA = "runtime-bench/v3"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_runtime.json")

#: bytes_per_load matches the PIC driver's 48 B/particle payload;
#: t_byte/lb_overhead put migration and planning overhead at the same
#: order as one step's imbalance excess, so the amortization trade-off
#: is actually exercised (an overhead of ~0 would trivially favor
#: rebalancing every step).  table2_strategies' trigger-policy section
#: imports this constant — retune in one place.
MODEL = rt_cost.RuntimeCostModel(t_load=1.0, t_byte=0.002,
                                 bytes_per_load=48.0, lb_overhead=30.0)
#: the predictive policy amortizes against the SAME model the bench
#: prices trajectories with — the comparison evaluates a coherent
#: policy, not one tuned to a different cost landscape
POLICIES = (
    ("every", "every"),
    ("threshold", "threshold"),
    ("predictive", rt_triggers.PredictiveTrigger(cost=MODEL)),
)
GATED = ("bimodal-churn", "adversarial-hotspot")


def _bench_scenarios(out, *, steps=200, lb_every=10, k=4):
    out["scenarios"] = {}
    for name in scenarios.available():
        prob, evolve = scenarios.get(name).instantiate()
        rows = []
        out["scenarios"][name] = {}
        for policy, spec in POLICIES:
            kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
                      strategy_kwargs=dict(k=k), trigger=spec, scan=True)
            simulator.run_series(prob, evolve, **kw)          # compile
            res, wall = timeit_median(
                lambda kw=kw: simulator.run_series(prob, evolve, **kw),
                repeat=REPEATS)
            modeled = float(
                rt_cost.series_modeled_seconds(res, MODEL).sum())
            out["scenarios"][name][policy] = dict(
                rebalances=float(res.lb_fired.sum()),
                mean_max_avg=float(res.max_avg.mean()),
                migrated_load=float(res.migrated_load.sum()),
                modeled_seconds=modeled,
                wall_seconds=wall,
            )
            rows.append([policy, int(res.lb_fired.sum()),
                         f"{res.max_avg.mean():.3f}",
                         f"{res.migrated_load.sum():.0f}",
                         f"{modeled:.0f}", f"{wall:.3f}"])
        print(f"\n{name}  (diff-comm k={k}, {steps} steps, "
              f"median of {REPEATS})")
        print(table(["trigger", "rebalances", "mean max/avg",
                     "migrated load", "modeled s", "wall s"], rows))


def _bench_pic(out, *, steps=60, lb_every=10):
    """Executed particle migration under fixed vs adaptive triggering."""
    base = dict(L=200, n_particles=20_000, steps=steps, k=2, rho=0.9,
                cx=10, cy=10, num_pes=8, mapping="striped",
                lb_every=lb_every, strategy="diff-comm",
                strategy_kwargs=dict(k=4))
    # the PIC predictive policy amortizes against the PIC CostModel
    # bridged into runtime terms (t_particle/t_byte/48 B per particle) —
    # at this toy scale the honest gate may rarely fire; the row reports
    # what the model actually recommends
    pic_predictive = rt_triggers.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel.from_pic(
            driver.CostModel(), strategy=base["strategy"],
            num_pes=base["num_pes"], bytes_per_particle=48.0))
    # v2: record which manifest build the executed exchange resolved to
    out["pic"] = dict(manifest_method=rt_migrate.resolve_method(
        "auto", n=base["n_particles"], num_nodes=base["num_pes"]))
    rows = []
    for policy in (None, "threshold", pic_predictive):
        cfg = driver.PICConfig(scan=True, trigger=policy, **base)
        driver.run(cfg)                                       # compile
        res, wall = timeit_median(lambda cfg=cfg: driver.run(cfg),
                                  repeat=REPEATS)
        s = res.summary()
        label = ("every" if policy is None
                 else policy if isinstance(policy, str) else "predictive")
        conserved = bool(res.final_x.shape[0] == base["n_particles"]
                         and np.isfinite(res.final_x).all())
        out["pic"][label] = dict(
            rebalances=float(res.lb_steps.sum()),
            migrated_bytes=float(res.migrated_bytes.sum()),
            modeled_time=s["modeled_time"],
            mean_max_avg=s["mean_max_avg"],
            particles_conserved=conserved,
            wall_seconds=wall,
        )
        rows.append([label, int(res.lb_steps.sum()),
                     f"{res.migrated_bytes.sum():.0f}",
                     f"{s['modeled_time']:.4f}", conserved])
        assert conserved, "executed migration must conserve particles"
    print(f"\nPIC driver 20k particles, {steps} steps, executed migration")
    print(table(["trigger", "rebalances", "migrated bytes (measured)",
                 "modeled s", "conserved"], rows))


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/runtime_bench.py", repeats=REPEATS,
        cost_model=dict(t_load=MODEL.t_load, t_byte=MODEL.t_byte,
                        bytes_per_load=MODEL.bytes_per_load,
                        lb_overhead=MODEL.lb_overhead),
        **out)


def run():
    out = {}
    _bench_scenarios(out)
    _bench_pic(out)

    path = save_result("runtime_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    for name in GATED:
        by = out["scenarios"][name]
        for policy in ("threshold", "predictive"):
            assert (by[policy]["modeled_seconds"]
                    < by["every"]["modeled_seconds"]), \
                f"{policy} must beat the fixed cadence on {name}: " \
                f"{by[policy]['modeled_seconds']:.0f} vs " \
                f"{by['every']['modeled_seconds']:.0f}"
    return out


if __name__ == "__main__":
    run()
