"""Paper Fig 5/6: PIC PRK strong scaling under Diffusion vs GreedyRefine.

The paper measures wall time on 1-8 Perlmutter nodes (128 PEs/node).  This
container has one core, so scaling is *modeled*: the PIC driver runs the
real algorithm at each PE count (same particles, same LB decisions) and the
step time is composed from a calibrated per-term cost model
(driver.CostModel): slowest-PE compute + inter-PE particle traffic + LB
planning amortization.  Reported per PE count:

  * modeled time/step for none / greedy-refine / diff-comm
  * mean + max external bytes (the Fig 6 communication-time proxy)

Paper claims asserted: diffusion's modeled step time ≤ GreedyRefine's at
every scale, and diffusion's external-byte traffic (the Fig-6 comm proxy)
is strictly lower.  NOT modeled: per-step synchronization wait (every PE
blocks on the slowest each iteration), which is what makes no-LB
catastrophic in the paper's real runs — the model therefore understates
the no-LB penalty, and we do not assert the paper's 7×-vs-none claim.
Calibration: comm-dominated regime (t_byte sized so comm ≈ compute at the
paper's 8-node point; see CostModel).

A **batched scenario sweep** rides along: before the per-PE-count study,
every registered scenario (``scenarios.batch_instances``) is replayed at a
common chare-level shape in one vmapped scan (``run_series_batch``) —
the scenario-diversity half of the Fig-5 story without a Python loop over
workloads.  Its per-scenario mean imbalance and aggregate throughput land
in the saved payload under ``batched_scenarios``."""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np

from benchmarks.common import save_result, table
from repro.pic import driver
from repro.sim import scenarios, simulator

SCALES = [4, 8, 16, 32]


def batched_scenario_sweep(*, batch: int = 8, steps: int = 60,
                           lb_every: int = 5, k: int = 3):
    """All registered scenarios in one vmapped scan (chare level)."""
    inst = scenarios.batch_instances(batch)
    kw = dict(steps=steps, lb_every=lb_every, strategy="diff-comm",
              strategy_kwargs=dict(k=k))
    simulator.run_series_batch(inst, **kw)            # compile
    t0 = time.perf_counter()
    bres = simulator.run_series_batch(inst, **kw)
    wall = time.perf_counter() - t0
    cell = {}
    rows = []
    for (name, _, _), s in zip(inst, bres.series):
        e = cell.setdefault(name, dict(lanes=0, mean_max_avg=0.0))
        e["lanes"] += 1
        e["mean_max_avg"] += float(s.max_avg.mean())
    for name, e in sorted(cell.items()):
        e["mean_max_avg"] /= e["lanes"]
        rows.append([name, e["lanes"], f"{e['mean_max_avg']:.3f}"])
    out = dict(batch=batch, steps=steps,
               lane_steps_per_sec=bres.lane_steps_per_sec,
               wall_seconds=wall, per_scenario=cell)
    print(f"batched scenario sweep: {batch} lanes × {steps} steps in "
          f"{wall:.3f}s ({bres.lane_steps_per_sec:.0f} lane-steps/sec)")
    print(table(["scenario", "lanes", "mean max/avg"], rows))
    return out


def _warmup(pes: int, cx: int, cy: int, L: int):
    """Compile the diffusion planner for this (chares, PEs) shape so the
    modeled LB cost is the steady-state per-call time, not XLA compile
    (the paper's Charm++ planner has no JIT; including our one-off compile
    in the step-time model would compare apples to oranges)."""
    import numpy as np

    from repro.core import api
    from repro.pic import chares as ch

    loads = np.random.default_rng(0).random(cx * cy).astype(np.float32) + 0.1
    assignment = ch.initial_mapping(cx, cy, pes, "striped")
    prob = ch.build_problem(loads, assignment, L=L, cx=cx, cy=cy,
                            num_pes=pes, k=4, vy0=1.0, lb_period=5)
    api.run_strategy("diff-comm", prob, k=3)


def run(n: int = 200_000, L: int = 1200, steps: int = 50,
        scenario: str = "pic-geometric",
        sharded: Optional[bool] = None):
    # particle mode / mapping / density come from the scenario registry;
    # charge k, the chare grid and the PE scales stay the Fig-5
    # strong-scaling setup.
    #
    # ``sharded``: plan with the mesh-sharded distributed planner
    # (distributed/lb_shard.py) instead of the single-device engine —
    # the scaling figure then comes from genuinely distributed planning
    # (ppermute halo exchanges per diffusion sweep).  Auto-on when the
    # process sees more than one device (e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8); the two
    # planners produce identical assignments (tests/test_lb_shard.py),
    # so the figure itself is invariant.
    if sharded is None:
        sharded = len(jax.devices()) > 1
    diff_name = "diff-comm"
    if sharded:
        from repro.distributed import lb_shard  # noqa: F401  (registers)
        diff_name = "diff-comm-sharded"
        print(f"planning with the mesh-sharded engine over "
              f"{len(jax.devices())} devices")
    sc = dict(scenarios.get(scenario).pic_config or {})
    out = {"batched_scenarios": batched_scenario_sweep(),
           "sharded_planner": bool(sharded)}
    rows = []
    for pes in SCALES:
        cell = {}
        _warmup(pes, 20, 10, L)
        for strat in ["none", "greedy-refine", diff_name]:
            kw = dict(k=3) if strat.startswith("diff") else {}
            cfg = driver.PICConfig(
                L=L, n_particles=n, steps=steps, k=4,
                rho=sc.get("rho", 0.9), mode=sc.get("mode", "GEOMETRIC"),
                cx=20, cy=10, num_pes=pes,
                mapping=sc.get("mapping", "striped"), lb_every=5,
                strategy=strat, strategy_kwargs=kw)
            r = driver.run(cfg)
            cell[strat] = dict(
                modeled_time=float(r.step_seconds.sum()),
                mean_ext=float(r.ext_bytes.mean()),
                max_avg=float(r.max_avg.mean()),
                lb_seconds=float(r.lb_seconds),
            )
        out[pes] = cell
        rows.append([
            pes,
            f"{cell['none']['modeled_time']:.3f}",
            f"{cell['greedy-refine']['modeled_time']:.3f}",
            f"{cell[diff_name]['modeled_time']:.3f}",
            f"{cell[diff_name]['modeled_time'] / cell['greedy-refine']['modeled_time']:.2f}",
            f"{cell[diff_name]['mean_ext'] / max(cell['greedy-refine']['mean_ext'], 1):.2f}",
        ])
    print(f"Fig 5 — modeled strong scaling, {n} particles {L}x{L} "
          f"(cost model: compute+comm+LB)")
    print(table(["PEs", "none (s)", "greedy (s)", "diff (s)",
                 "diff/greedy", "ext ratio"], rows))
    # paper: diffusion <= greedy at every scale.  Asserted on the
    # single-device planner only: under the sharded planner the measured
    # planning wall includes the CPU mesh-*emulation* overhead (the
    # virtual devices timeshare one core), which the cost model would
    # charge as real distributed planning time.  The sharded plans are
    # identical to the single-device ones (tests/test_lb_shard.py), so
    # the claims carry over; the sharded run is about producing the
    # figure with genuinely distributed planning, not re-timing it.
    if not sharded:
        for pes in SCALES:
            assert (out[pes][diff_name]["modeled_time"]
                    <= out[pes]["greedy-refine"]["modeled_time"] * 1.05), pes
        # no-LB scales worst: its time barely improves from 4 to max PEs
        t_none = [out[p]["none"]["modeled_time"] for p in SCALES]
        t_diff = [out[p][diff_name]["modeled_time"] for p in SCALES]
        assert (t_diff[-1] / t_diff[0]
                < t_none[-1] / max(t_none[0], 1e-9) + 0.5)
    save_result("fig5_scaling", out)
    return out


if __name__ == "__main__":
    run()
