"""Serving policy benchmark: diffusion + predictive vs greedy + fixed cadence.

Replays bursty multi-turn session fleets through the scan-compiled serving
replay (``serve/replay.py`` — the whole tick loop, trigger decision and
**executed** KV-slab exchange inside one ``lax.scan``) and prices the two
things a serving operator actually pays: replica load imbalance (p95 of
the per-tick max/avg — tail latency pressure) and the total KV-cache
bytes migration moved over the wire.  The headline gate: the paper's
comm-aware diffusion planner with the predictive trigger must beat the
``greedy`` rebalance-everything baseline on a fixed cadence **on both
axes at once** — no better tail balance bought with more KV traffic, and
vice versa.  Asserted on the synthetic workload and on a recorded trace.

A 10⁵-session fleet entry reports scanned-replay wall time and throughput
(ticks/s) at production scale — reported honestly, not gated: on the CI
CPU the number measures XLA host throughput, not an accelerator serving
tier.

Results are written twice: ``artifacts/bench/serve_bench.json`` (legacy
location) and the stable-schema ``BENCH_serve.json`` at the repo root
(schema ``serve-bench/v1``; keys are append-only; committed +
CI-uploaded).

  PYTHONPATH=src:. python benchmarks/serve_bench.py
"""
from __future__ import annotations

import json
import os

SCHEMA = "serve-bench/v2"
REPEATS = 3
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")

#: trigger cost model for the gated runs: KV bytes priced so a fleet-wide
#: exchange (~1e4 KV bytes at the bench scale) costs the same order as
#: the imbalance-time the horizon projects (~1e2 load-seconds) — the
#: regime where the measured predictive gate has a real decision to make
#: (t_byte=1 would silence it forever after one fire; t_byte=0 would
#: fire it every eligible tick)
T_BYTE = 2e-3


def _policies():
    from repro.runtime.cost import RuntimeCostModel
    from repro.runtime.triggers import PredictiveTrigger

    cost = RuntimeCostModel(t_byte=T_BYTE, lb_overhead=1.0)
    return {
        "diff-comm+predictive": dict(
            strategy="diff-comm+predictive",
            trigger=PredictiveTrigger(cost=cost)),
        "greedy+every": dict(strategy="greedy", trigger="every"),
    }


def _replay_one(workload, steps, policy):
    import numpy as np

    from benchmarks.common import timeit_median
    from repro.serve import replay as sr

    res, wall = timeit_median(
        lambda: sr.run_serve_replay(workload, steps=steps, lb_every=10,
                                    **policy),
        repeat=REPEATS)
    return dict(
        p95_imbalance=float(np.percentile(res.max_avg, 95)),
        mean_imbalance=float(res.max_avg.mean()),
        moved_kv_bytes=float(res.total_moved_kv),
        moved_sessions=float(res.moved_sessions.sum()),
        rebalances=float(res.lb_fired.sum()),
        prefix_locality=float(res.prefix_local.mean()),
        scanned=bool(res.scanned),
        wall_seconds=wall,
    )


def _bench_policies(out, *, steps=120):
    """The gated comparison, on synthetic traffic and a recorded trace."""
    from benchmarks.common import table
    from repro.serve import replay as sr

    synth = sr.ServeWorkload(num_sessions=2048, num_replicas=16, seed=0)
    trace = sr.record_trace(
        sr.ServeWorkload(num_sessions=1024, num_replicas=8,
                         burst_period=18, seed=3),
        steps=steps)
    out["workloads"] = {}
    for wname, (w, T) in {"synthetic": (synth, steps),
                          "trace": (trace, steps)}.items():
        entry = dict(num_sessions=w.num_sessions,
                     num_replicas=w.num_replicas, steps=T, policies={})
        rows = []
        for pname, policy in _policies().items():
            r = _replay_one(w, T, policy)
            entry["policies"][pname] = r
            rows.append([pname, int(r["rebalances"]),
                         f"{r['p95_imbalance']:.3f}",
                         f"{r['moved_kv_bytes']:.0f}",
                         f"{r['prefix_locality']:.3f}",
                         f"{r['wall_seconds']:.3f}"])
        diff = entry["policies"]["diff-comm+predictive"]
        base = entry["policies"]["greedy+every"]
        entry["gates"] = dict(
            p95_imbalance_no_worse=diff["p95_imbalance"]
            <= base["p95_imbalance"],
            moved_kv_no_more=diff["moved_kv_bytes"]
            <= base["moved_kv_bytes"],
        )
        out["workloads"][wname] = entry
        print(f"\n{wname}: S={w.num_sessions} R={w.num_replicas} T={T} "
              f"(median of {REPEATS})")
        print(table(["policy", "fires", "p95 max/avg", "moved KV",
                     "prefix-local", "wall s"], rows))
        assert entry["gates"]["p95_imbalance_no_worse"], (
            f"{wname}: diffusion+predictive p95 imbalance "
            f"{diff['p95_imbalance']:.3f} worse than greedy "
            f"{base['p95_imbalance']:.3f}")
        assert entry["gates"]["moved_kv_no_more"], (
            f"{wname}: diffusion+predictive moved "
            f"{diff['moved_kv_bytes']:.0f} KV bytes > greedy "
            f"{base['moved_kv_bytes']:.0f}")


def _bench_scale(out, *, num_sessions=131_072, num_replicas=64, steps=30):
    """10⁵⁺-session fleet through the scanned replay — wall reported,
    not gated (CPU CI measures XLA host throughput)."""
    import numpy as np

    from benchmarks.common import table, timeit_median
    from repro.serve import replay as sr

    w = sr.ServeWorkload(num_sessions=num_sessions,
                         num_replicas=num_replicas, seed=1)
    # fixed cadence: the scale entry measures replay throughput with
    # executed exchanges on every fire, so the fire count must not
    # depend on how a cost model prices a 10⁵-session fleet
    res, wall = timeit_median(
        lambda: sr.run_serve_replay(
            w, steps=steps, lb_every=10, strategy="diff-comm",
            trigger="every"),
        repeat=REPEATS)
    assert np.isfinite(res.max_avg).all()
    assert int(res.lb_fired.sum()) > 0 and res.total_moved_kv > 0
    out["scale"] = dict(
        num_sessions=num_sessions,
        num_replicas=num_replicas,
        steps=steps,
        rebalances=float(res.lb_fired.sum()),
        moved_kv_bytes=float(res.total_moved_kv),
        p95_imbalance=float(np.percentile(res.max_avg, 95)),
        wall_seconds=wall,
        ticks_per_second=steps / max(wall, 1e-9),
        session_ticks_per_second=num_sessions * steps / max(wall, 1e-9),
    )
    print(f"\nscale: S={num_sessions} R={num_replicas} T={steps} "
          f"(median of {REPEATS})")
    print(table(
        ["fires", "moved KV", "p95 max/avg", "wall s", "session-ticks/s"],
        [[int(res.lb_fired.sum()), f"{res.total_moved_kv:.0f}",
          f"{out['scale']['p95_imbalance']:.3f}", f"{wall:.3f}",
          f"{out['scale']['session_ticks_per_second']:.2e}"]]))


def write_bench_json(out) -> str:
    """Stable-schema perf-trajectory artifact at the repo root."""
    from benchmarks import common

    return common.write_bench_json(
        BENCH_PATH, schema=SCHEMA,
        generated_by="benchmarks/serve_bench.py", repeats=REPEATS,
        **out)


def run():
    import jax

    from benchmarks.common import save_result

    out = {"devices": len(jax.devices()),
           "backend": jax.default_backend(),
           "t_byte": T_BYTE}
    _bench_policies(out)
    _bench_scale(out)

    path = save_result("serve_bench", out)
    bench_path = write_bench_json(out)
    print(f"\nsaved {path}\nsaved {bench_path}")
    return out


if __name__ == "__main__":
    run()
