"""Paper Fig 4: PIC PRK max/avg particles per PE over time under load
balancing.  100k particles, 1000² grid, k=2, ρ=0.9, 12×12 chares, 4 PEs,
LB every 10 iterations, diffusion with 4 neighbors (capped by P-1).

Paper claim: GreedyRefine and Coordinate-Diffusion ≈50% improvement in the
mean max/avg ratio vs no LB; Communication-Diffusion ≈48%."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.pic import driver
from repro.sim import scenarios

PAPER_IMPROVEMENT = {"greedy-refine": 0.50, "diff-comm": 0.48,
                     "diff-coord": 0.50}


def run(steps: int = 100, n: int = 100_000, L: int = 1000,
        scenario: str = "pic-geometric"):
    # workload parameters come from the scenario registry (sim/scenarios.py)
    sc = dict(scenarios.get(scenario).pic_config or {})
    base = dict(L=L, n_particles=n, steps=steps,
                k=sc.get("k", 2), rho=sc.get("rho", 0.9),
                mode=sc.get("mode", "GEOMETRIC"),
                cx=sc.get("cx", 12), cy=sc.get("cy", 12),
                num_pes=sc.get("num_pes", 4),
                mapping=sc.get("mapping", "striped"),
                lb_every=sc.get("lb_every", 10))
    out = {}
    res = {}
    cost_model = driver.CostModel()
    # the trigger-wrapped registry variant rides along so the adaptive
    # policy's executed-exchange cost sits next to the fixed cadence
    strategies = ["none", "greedy-refine", "diff-comm", "diff-coord",
                  "diff-comm+threshold"]
    for strat in strategies:
        kw = dict(k=3) if strat.startswith("diff") else {}
        cfg = driver.PICConfig(strategy=strat, strategy_kwargs=kw, **base)
        r = driver.run(cfg, cost_model)
        res[strat] = r
        out[strat] = r.summary()
        out[strat]["max_avg_series"] = r.max_avg.tolist()
        # honest per-strategy migration cost: executed-exchange bytes on
        # the wire plus the (amortized) planning overhead, in modeled
        # seconds — measured from the executed manifests, not estimated
        out[strat]["migration_cost_seconds"] = float(
            r.migrated_bytes.sum() * cost_model.t_byte
            + cost_model.lb_seconds(r.lb_seconds, strat, base["num_pes"]))

    base_ma = res["none"].max_avg.mean()
    rows = []
    for strat in strategies[1:]:
        imp = 1 - res[strat].max_avg.mean() / base_ma
        out[strat]["improvement"] = imp
        paper = PAPER_IMPROVEMENT.get(strat)
        rows.append([strat, f"{res[strat].max_avg.mean():.2f}",
                     f"{imp*100:.1f}%",
                     f"{paper*100:.0f}%" if paper is not None else "-",
                     f"{res[strat].ext_bytes.mean():.0f}",
                     f"{res[strat].migrated_bytes.sum():.2e}",
                     f"{out[strat]['migration_cost_seconds']:.4f}"])
    print(f"Fig 4 — PIC PRK {n} particles {L}x{L}, k=2 rho=0.9, "
          f"LB/10 it (no-LB mean max/avg {base_ma:.2f})")
    print(table(["strategy", "mean max/avg", "improv", "paper",
                 "ext bytes/step", "migr bytes (measured)",
                 "migr cost s"], rows))
    for strat in ["greedy-refine", "diff-comm", "diff-coord"]:
        assert out[strat]["improvement"] > 0.25, \
            f"{strat}: LB must substantially improve balance"
    # diffusion moves less data across PEs than greedy-refine (paper §VI.C)
    assert (res["diff-comm"].ext_bytes.mean()
            < res["greedy-refine"].ext_bytes.mean() * 1.1)
    save_result("fig4_pic_lb", out)
    return out


if __name__ == "__main__":
    run()
