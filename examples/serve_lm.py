"""Batched serving example: continuous batching + diffusion scheduling.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
