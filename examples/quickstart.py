"""Quickstart: the paper's balancer on a toy problem in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import api, make_problem, metrics
from repro.sim import viz

# 8x8 grid of objects on 4 nodes, 5-point-stencil communication
from repro.sim import stencil, synthetic

problem = stencil.stencil_2d(8, 8, 4, mapping="tiled")

# inject imbalance: node 0's objects get 5x the load
problem = synthetic.hotspot(problem, node=0, factor=5.0)

print("before:", metrics.evaluate(problem))
print(viz.ownership_map(np.asarray(problem.assignment), 8, 8))

# run the paper's three-stage communication-aware diffusion with K=2
plan = api.diffusion_lb(problem, k=2, variant="comm")

print("\nafter:", metrics.evaluate(problem, plan.assignment))
print(viz.ownership_map(plan.assignment, 8, 8))
print("\nplan info:", {k: round(v, 4) if isinstance(v, float) else v
                       for k, v in plan.info.items()})
