"""End-to-end LM training driver (assignment deliverable b): train a ~100M
model for a few hundred steps with checkpointing and fault-tolerant
supervision.

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --smoke    # tiny, 20 steps

The default full run instantiates smollm-135m (the assigned ~135M-param
config) at its real width/depth but a reduced sequence length/batch so a
few hundred steps finish on CPU.  Use --arch to pick any other assigned
architecture's reduced config.
"""
import argparse
import dataclasses
import tempfile

from repro.launch.train import RunConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--full-width", action="store_true",
                    help="use the arch's FULL config (needs memory)")
    args = ap.parse_args()

    steps = args.steps or (20 if args.smoke else 200)
    with tempfile.TemporaryDirectory() as d:
        cfg = RunConfig(
            arch=args.arch,
            reduced=not args.full_width,
            steps=steps,
            seq_len=64 if args.smoke else 256,
            global_batch=4 if args.smoke else 8,
            lr=1e-3,
            warmup=steps // 10,
            save_every=max(steps // 4, 1),
            ckpt_dir=d,
            log_every=max(steps // 20, 1),
        )
        out = train(cfg)
        print(f"\nfinal loss {out['final_loss']:.4f} "
              f"(start {out['losses'][0]:.4f}) in {out['seconds']:.1f}s — "
              f"loss must decrease on the synthetic stream")


if __name__ == "__main__":
    main()
