"""End-to-end PIC PRK driver run with diffusion load balancing (paper §VI).

  PYTHONPATH=src python examples/pic_prk_run.py [--particles 100000]
"""
import argparse

import numpy as np

from repro.pic import driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=50_000)
    ap.add_argument("--grid", type=int, default=500)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--pes", type=int, default=4)
    ap.add_argument("--strategy", default="diff-comm",
                    choices=["none", "greedy-refine", "diff-comm",
                             "diff-coord", "metis", "parmetis", "greedy"])
    args = ap.parse_args()

    cfg = driver.PICConfig(
        L=args.grid, n_particles=args.particles, steps=args.steps,
        k=2, rho=0.9, cx=12, cy=12, num_pes=args.pes, mapping="striped",
        lb_every=10, strategy=args.strategy,
        strategy_kwargs=dict(k=3) if args.strategy.startswith("diff") else {})
    print(f"PIC PRK: {args.particles} particles on {args.grid}² grid, "
          f"{args.pes} PEs, strategy={args.strategy}")
    r = driver.run(cfg)
    s = r.summary()
    print(f"mean max/avg particles per PE: {s['mean_max_avg']:.3f}")
    print(f"mean external bytes/step:      {s['mean_ext_bytes']:.0f}")
    print(f"LB planning time total:        {s['lb_seconds']:.2f}s")
    print(f"modeled runtime:               {s['modeled_time']:.4f}s")
    print("max/avg trajectory:",
          " ".join(f"{v:.2f}" for v in r.max_avg[::max(args.steps // 15, 1)]))


if __name__ == "__main__":
    main()
