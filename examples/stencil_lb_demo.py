"""Strategy comparison on time-evolving workloads (paper §V).

Replays every registered scenario (sim/scenarios.py) under periodic
rebalancing and prints per-strategy trajectories — the simulator-level
version of the paper's Fig 4.  Jittable strategies (none / diff-*) run on
the scan-compiled device-resident path; NumPy baselines (greedy-refine)
fall back to the host loop — same ``run_series`` call either way.

  PYTHONPATH=src python examples/stencil_lb_demo.py
"""
from repro.sim import scenarios, simulator

STRATEGIES = ["none", "greedy-refine", "diff-comm", "diff-coord"]


def main():
    for name in scenarios.available():
        sc = scenarios.get(name)
        problem, evolve = sc.instantiate()
        print(f"\n=== {name}: {sc.description}")
        print(f"{'strategy':>14} {'mean max/avg':>13} {'mean ext/int':>13} "
              f"{'migr/step':>10} {'path':>8} {'wall s':>8}")
        for strategy in STRATEGIES:
            kw = dict(k=4) if strategy.startswith("diff") else {}
            if strategy == "diff-coord" and problem.coords is None:
                continue
            res = simulator.run_series(
                problem, evolve, steps=60, lb_every=5, strategy=strategy,
                strategy_kwargs=kw)
            mig = (res.migrations[res.migrations > 0].mean()
                   if (res.migrations > 0).any() else 0.0)
            print(f"{strategy:>14} {res.max_avg.mean():>13.3f} "
                  f"{res.ext_int.mean():>13.3f} {mig:>10.3f} "
                  f"{'scan' if res.scanned else 'host':>8} "
                  f"{res.wall_seconds:>8.3f}")


if __name__ == "__main__":
    main()
