"""Strategy comparison on a time-evolving stencil workload (paper §V).

Runs 60 steps of a 2D stencil whose load hotspot orbits the grid, with
periodic rebalancing, and prints per-strategy trajectories — the
simulator-level version of the paper's Fig 4.

  PYTHONPATH=src python examples/stencil_lb_demo.py
"""
import dataclasses

import numpy as np

from repro.core import comm_graph
from repro.sim import simulator, stencil, synthetic


def make_evolver(base_loads: np.ndarray, coords: np.ndarray, grid: int):
    """Load hotspot orbiting the domain: load_i(t) ∝ 1 + 8·exp(-d²/2σ²)."""

    def evolve(problem: comm_graph.LBProblem, t: int):
        angle = 2 * np.pi * t / 60.0
        cx = grid / 2 + grid / 3 * np.cos(angle)
        cy = grid / 2 + grid / 3 * np.sin(angle)
        d2 = ((coords[:, 0] - cx) ** 2 + (coords[:, 1] - cy) ** 2)
        loads = base_loads * (1 + 8 * np.exp(-d2 / (2 * (grid / 8) ** 2)))
        return dataclasses.replace(problem,
                                   loads=loads.astype(np.float32))

    return evolve


def main():
    grid, pes = 32, 16
    base = stencil.stencil_2d(grid, grid, pes, mapping="tiled")
    coords = np.asarray(base.coords)
    base_loads = np.ones(grid * grid, np.float32)
    evolve = make_evolver(base_loads, coords, grid)

    print(f"orbiting hotspot on {grid}x{grid} stencil, {pes} PEs, LB/5 steps")
    print(f"{'strategy':>14} {'mean max/avg':>13} {'mean ext/int':>13} "
          f"{'migr/step':>10}")
    for strategy in ["none", "greedy-refine", "diff-comm", "diff-coord"]:
        kw = dict(k=4) if strategy.startswith("diff") else {}
        res = simulator.run_series(
            base, evolve, steps=60, lb_every=5, strategy=strategy,
            strategy_kwargs=kw)
        print(f"{strategy:>14} {res.max_avg.mean():>13.3f} "
              f"{res.ext_int.mean():>13.3f} "
              f"{res.migrations[res.migrations > 0].mean() if (res.migrations > 0).any() else 0:>10.3f}")


if __name__ == "__main__":
    main()
